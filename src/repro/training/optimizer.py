"""AdamW + LR schedules in pure JAX (no optax dependency).

Optimizer moments are fp32 regardless of parameter dtype; the update is
computed in fp32 and cast back (bf16-weight training).  Global-norm clipping
is fused into the update.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["OptimizerConfig", "init_opt_state", "adamw_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to ``min_lr_ratio * lr``."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.learning_rate * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(np.pi * frac)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: OptimizerConfig, params, grads, opt_state):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    corr1 = 1.0 - b1 ** step.astype(jnp.float32)
    corr2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = lr_at(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / corr1
        vhat = v2 / corr2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["mu"])
    flat_v = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
