"""Deterministic, resumable data pipeline.

Counter-based RNG (numpy Philox keyed on (seed, step)) gives O(1) random
access to any batch: restart-from-checkpoint reproduces the exact stream
without replaying, and elastic re-sharding just re-slices the same global
batch.  A file-backed mode memory-maps a token file for real corpora.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["DataConfig", "SyntheticTokens", "FileTokens", "make_pipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    path: Optional[str] = None  # file-backed when set


class SyntheticTokens:
    """Zipf-ish synthetic token stream (harder than uniform for training)."""

    def __init__(self, cfg: DataConfig, model: ModelConfig):
        self.cfg = cfg
        self.model = model

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c, m = self.cfg, self.model
        rng = np.random.Generator(np.random.Philox(key=(c.seed, step)))
        shape = (c.batch_size, c.seq_len + 1)
        # Zipf over the vocab, clipped; plus a little local structure
        # (repeat-previous-token) so models can actually learn something.
        z = rng.zipf(1.3, size=shape)
        toks = np.minimum(z - 1, m.vocab_size - 1).astype(np.int32)
        repeat = rng.random(shape) < 0.3
        toks[:, 1:] = np.where(repeat[:, 1:], toks[:, :-1], toks[:, 1:])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if m.frontend == "audio":
            frames = rng.normal(size=(c.batch_size, c.seq_len, m.frontend_dim))
            batch = {
                "frames": frames.astype(np.float32),
                "labels": toks[:, 1:],
            }
        elif m.frontend == "vision":
            patches = rng.normal(
                size=(c.batch_size, m.num_prefix_tokens, m.frontend_dim)
            )
            batch["patches"] = patches.astype(np.float32)
        return batch


class FileTokens:
    """Memory-mapped int32 token file; deterministic strided access."""

    def __init__(self, cfg: DataConfig, model: ModelConfig):
        self.cfg = cfg
        self.model = model
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(key=(c.seed, step)))
        idx = rng.integers(0, self.n_windows, size=c.batch_size)
        rows = np.stack(
            [self.data[i * c.seq_len : i * c.seq_len + c.seq_len + 1] for i in idx]
        )
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }


def make_pipeline(cfg: DataConfig, model: ModelConfig):
    if cfg.path:
        return FileTokens(cfg, model)
    return SyntheticTokens(cfg, model)
