"""Observability for the serving stack: tracing, metrics, exporters.

The stack's seven stages (client → admission/tenancy → controller →
scheduler → loop → cluster/transport → backend) previously reported only
through post-hoc :func:`repro.core.sla.summarize`.  This package adds the
production lens:

* :mod:`repro.observability.trace` — ``Tracer``/``Span`` with explicit
  parent links and ``perf_counter``-ms stamps: one span tree per request
  plus loop-tick / controller / transport-worker spans.
* :mod:`repro.observability.metrics` — counters, gauges, and fixed-layout
  log-bucketed latency histograms (O(1) recording, mergeable snapshots,
  percentile accessor).
* :mod:`repro.observability.export` — Chrome ``trace_event`` JSON
  (``chrome://tracing`` / Perfetto), Prometheus text, JSONL span sink,
  and the request-conservation audit.
* :mod:`repro.observability.quantile` — the one shared, empty-input-safe
  percentile helper every summary path uses.

:class:`Observability` bundles one tracer + one registry; it is threaded
through the stack as an *optional* handle (``observability=None``
everywhere by default) following the repo's regression-pin convention:
with it unset, every instrumented layer takes its exact pre-PR path —
byte-identical, seeded-twin-pinned in ``tests/test_observability.py``.
"""
from __future__ import annotations

from repro.observability.export import (
    chrome_trace,
    prometheus_text,
    request_conservation,
    write_chrome_trace,
    write_jsonl_spans,
    write_metrics_snapshot,
    write_prometheus,
)
from repro.observability.metrics import (
    Counter,
    Gauge,
    Histogram,
    HistogramSnapshot,
    MetricsRegistry,
    N_BUCKETS,
)
from repro.observability.quantile import percentiles, quantile
from repro.observability.trace import Span, Tracer, now_wall_ms

__all__ = [
    "Observability",
    "Tracer",
    "Span",
    "now_wall_ms",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramSnapshot",
    "N_BUCKETS",
    "quantile",
    "percentiles",
    "chrome_trace",
    "prometheus_text",
    "request_conservation",
    "write_chrome_trace",
    "write_jsonl_spans",
    "write_metrics_snapshot",
    "write_prometheus",
]


class Observability:
    """One tracer + one metrics registry: the handle the stack threads.

    Attach it once at the top (``ServingLoop(...,
    observability=obs)``) — the loop propagates it to the admission
    queue, tenant lanes, controller, scheduler, cluster (and through it
    each replica's breaker and transport), and the backend's slot cache.
    """

    def __init__(self):
        self.tracer = Tracer()
        self.metrics = MetricsRegistry()

    # Convenience passthroughs for the hot instrumentation sites.
    def counter(self, name: str, **labels):
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels):
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, **labels):
        return self.metrics.histogram(name, **labels)
