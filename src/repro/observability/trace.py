"""Tracing layer: explicit-parent spans with ``perf_counter``-ms stamps.

One :class:`Tracer` per :class:`~repro.observability.Observability` handle
collects :class:`Span` records from every stage of the serving stack.  A
span is deliberately dumb — a name, a category, an optional display
``track``, explicit ``parent_id`` linkage, start/end stamps in
``time.perf_counter() * 1e3`` milliseconds, and a small ``args`` dict —
so recording is a list append under a lock and the exporters
(:mod:`repro.observability.export`) own all formatting.

Span taxonomy (producers across the stack):

* per request — ``request`` root (one per submitted request, on its
  tenant lane's track), ``queued`` (submit → tick claim), ``remote`` /
  ``ondevice`` tier legs (dispatch → done wall stamps, on the serving
  replica's track), and instants: ``scheduled``, ``ttft``,
  ``stream.token``, ``requeue``, ``resolve`` / ``shed`` / ``cancel``
  (exactly one terminal instant per request — the conservation check).
* per tick — ``tick`` on the ``loop`` track, plus ``batch:<variant>``
  group spans on each replica's track.
* transport — ``transport.roundtrip`` with a nested ``worker.execute``
  reconstructed from the worker-side stamps that ride the completion
  message (see :mod:`repro.serving.transport`).
* control plane — ``controller.retune`` and ``breaker.trip`` instants.

Cross-thread / cross-layer parentage uses a thread-local *ambient* span:
a dispatching layer binds its span (:meth:`Tracer.bind`), and a deeper
layer that cannot receive the span through its call signature (the
transport under the generic ``run_batch`` protocol) picks it up with
:meth:`Tracer.ambient_id`.

Span ids are small ints assigned in creation order — deterministic for a
fixed call sequence, which is what lets tests pin span trees.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "now_wall_ms"]


def now_wall_ms() -> float:
    """The tracer clock: ``time.perf_counter()`` in milliseconds."""
    return time.perf_counter() * 1e3


class Span:
    """One timed (or instant) event; linked to its parent by id."""

    __slots__ = (
        "span_id", "parent_id", "name", "cat", "track",
        "start_ms", "end_ms", "args",
    )

    def __init__(
        self,
        span_id: int,
        parent_id: Optional[int],
        name: str,
        cat: str,
        track: Optional[str],
        start_ms: float,
        args: Optional[Dict],
    ):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.cat = cat
        self.track = track
        self.start_ms = start_ms
        self.end_ms: Optional[float] = None  # None while open; == start: instant
        self.args = args if args is not None else {}

    @property
    def duration_ms(self) -> Optional[float]:
        return None if self.end_ms is None else self.end_ms - self.start_ms

    @property
    def is_instant(self) -> bool:
        return self.end_ms == self.start_ms

    def to_dict(self) -> Dict:
        """JSONL wire form (the span-sink exporter's row format)."""
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "cat": self.cat,
            "track": self.track,
            "start_ms": self.start_ms,
            "end_ms": self.end_ms,
            "args": self.args,
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "open" if self.end_ms is None else f"{self.duration_ms:.3f}ms"
        return f"Span({self.span_id}, {self.name!r}, {state})"


class Tracer:
    """Append-only span collector; thread-safe, export-agnostic."""

    def __init__(self):
        self._lock = threading.Lock()
        self._next_id = 0
        self.spans: List[Span] = []
        self._tl = threading.local()  # per-thread ambient-parent stack

    # -- recording ------------------------------------------------------------
    def start(
        self,
        name: str,
        *,
        parent=None,
        cat: str = "",
        track: Optional[str] = None,
        t0_ms: Optional[float] = None,
        **args,
    ) -> Span:
        """Open a span.  ``parent`` is a :class:`Span` or a raw span id."""
        parent_id = parent.span_id if isinstance(parent, Span) else parent
        t0 = now_wall_ms() if t0_ms is None else float(t0_ms)
        with self._lock:
            span = Span(self._next_id, parent_id, name, cat, track, t0, args)
            self._next_id += 1
            self.spans.append(span)
        return span

    def end(self, span: Span, t1_ms: Optional[float] = None) -> Span:
        """Close a span (idempotent — the first close wins)."""
        if span.end_ms is None:
            t1 = now_wall_ms() if t1_ms is None else float(t1_ms)
            span.end_ms = max(t1, span.start_ms)
        return span

    def instant(
        self,
        name: str,
        *,
        parent=None,
        cat: str = "",
        track: Optional[str] = None,
        t_ms: Optional[float] = None,
        **args,
    ) -> Span:
        """A zero-duration mark (``start_ms == end_ms``)."""
        span = self.start(
            name, parent=parent, cat=cat, track=track, t0_ms=t_ms, **args
        )
        span.end_ms = span.start_ms
        return span

    @contextlib.contextmanager
    def span(self, name: str, **kw):
        """``with tracer.span("tick") as s:`` — start, yield, end."""
        s = self.start(name, **kw)
        try:
            yield s
        finally:
            self.end(s)

    # -- ambient (thread-local) parentage --------------------------------------
    def _stack(self) -> List[Optional[int]]:
        stack = getattr(self._tl, "stack", None)
        if stack is None:
            stack = self._tl.stack = []
        return stack

    def ambient_id(self) -> Optional[int]:
        """The current thread's innermost bound span id (None: unbound)."""
        stack = self._stack()
        return stack[-1] if stack else None

    @contextlib.contextmanager
    def bind(self, span) -> "contextlib.AbstractContextManager":
        """Make ``span`` (a Span, an id, or None) the thread's ambient
        parent for the duration of the block."""
        span_id = span.span_id if isinstance(span, Span) else span
        stack = self._stack()
        stack.append(span_id)
        try:
            yield
        finally:
            stack.pop()

    # -- inspection -------------------------------------------------------------
    def find(self, name: str) -> List[Span]:
        """All spans with ``name`` (creation order)."""
        with self._lock:
            return [s for s in self.spans if s.name == name]

    def children_of(self, span) -> List[Span]:
        span_id = span.span_id if isinstance(span, Span) else span
        with self._lock:
            return [s for s in self.spans if s.parent_id == span_id]

    def __len__(self) -> int:
        return len(self.spans)
