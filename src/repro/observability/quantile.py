"""Shared quantile math for summaries, CLIs, and histograms.

Every layer that reports a tail — :func:`repro.core.sla.summarize`, the
``launch/serve`` summary block, the benchmark derived strings, and the
log-bucketed histograms' percentile accessor — routes through this one
helper, so "p99" means the same interpolation everywhere (NumPy's
``linear`` method: the historical ``np.percentile`` default every
regression pin was measured under).

The helpers are *empty-input-safe*: an empty sample returns ``default``
(NaN unless overridden) instead of raising — a shed-everything tick or a
zero-completion run reports an honest "no data" rather than crashing the
summary path.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["quantile", "percentiles"]


def quantile(values, q: float, default: float = float("nan")) -> float:
    """The ``q``-th percentile (0-100) of ``values``, linear interpolation.

    Matches ``np.percentile(values, q)`` exactly on non-empty input;
    returns ``default`` on an empty sample.
    """
    arr = np.asarray(values, dtype=float).ravel()
    if arr.size == 0:
        return float(default)
    return float(np.percentile(arr, q))


def percentiles(
    values, qs: Sequence[float], default: float = float("nan")
) -> List[float]:
    """Vector form of :func:`quantile`: one value per entry of ``qs``."""
    arr = np.asarray(values, dtype=float).ravel()
    if arr.size == 0:
        return [float(default)] * len(qs)
    return [float(v) for v in np.percentile(arr, list(qs))]
