"""Exporters: Chrome ``trace_event`` JSON, Prometheus text, JSONL spans.

Three sinks over the in-memory :class:`~repro.observability.trace.Tracer`
and :class:`~repro.observability.metrics.MetricsRegistry`:

* :func:`chrome_trace` — the Chrome ``trace_event`` JSON object format
  (loadable in ``chrome://tracing`` / Perfetto).  Every distinct span
  ``track`` becomes one named thread row (``tid``) under a single
  ``pid`` — one track per replica (``replica:<id>``), one per tenant
  lane (``tenant:<lane>``), plus the ``loop`` track — with timestamps in
  microseconds as the format requires.
* :func:`prometheus_text` — a Prometheus exposition-format snapshot:
  counters/gauges verbatim, histograms as cumulative ``_bucket{le=...}``
  series plus ``_sum`` / ``_count``.
* :func:`write_jsonl_spans` — one JSON object per span per line (the raw
  span sink for offline analysis).

:func:`request_conservation` is the trace-side accounting check the CI
smoke gate uses: every ``request`` root span must carry exactly one
terminal instant (``resolve`` | ``shed`` | ``cancel``) — submitted ==
resolved + rejected + cancelled, no request dropped on the floor.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional

from repro.observability.metrics import (
    MetricsRegistry,
    bucket_upper_ms,
)
from repro.observability.trace import Span, Tracer

__all__ = [
    "chrome_trace",
    "write_chrome_trace",
    "prometheus_text",
    "write_prometheus",
    "write_jsonl_spans",
    "write_metrics_snapshot",
    "request_conservation",
]

DEFAULT_TRACK = "loop"
_TERMINAL_NAMES = ("resolve", "shed", "cancel")


def _spans_of(source) -> List[Span]:
    return list(source.spans) if isinstance(source, Tracer) else list(source)


# -- Chrome trace_event ------------------------------------------------------
def chrome_trace(source, process_name: str = "repro-serving") -> Dict:
    """Build the Chrome ``trace_event`` JSON object for a span set.

    Unfinished spans are exported as zero-duration events at their start
    stamp (an interrupted run still loads).  ``args`` carries each span's
    ``span_id`` / ``parent_id`` so the tree survives the flat format.
    """
    spans = _spans_of(source)
    tracks: Dict[str, int] = {}
    events: List[Dict] = [
        {
            "ph": "M",
            "name": "process_name",
            "pid": 0,
            "tid": 0,
            "args": {"name": process_name},
        }
    ]

    def tid_for(track: Optional[str]) -> int:
        name = track if track is not None else DEFAULT_TRACK
        if name not in tracks:
            tracks[name] = len(tracks)
            events.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": 0,
                    "tid": tracks[name],
                    "args": {"name": name},
                }
            )
        return tracks[name]

    for s in spans:
        tid = tid_for(s.track)
        args = dict(s.args)
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        base = {
            "name": s.name,
            "cat": s.cat or "span",
            "pid": 0,
            "tid": tid,
            "ts": s.start_ms * 1e3,  # trace_event timestamps are in µs
            "args": args,
        }
        if s.is_instant:
            base.update(ph="i", s="t")  # thread-scoped instant
        else:
            end = s.start_ms if s.end_ms is None else s.end_ms
            base.update(ph="X", dur=max(end - s.start_ms, 0.0) * 1e3)
        events.append(base)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, source, **kw) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(source, **kw), f)


# -- Prometheus text ---------------------------------------------------------
def _fmt_labels(labels: Dict[str, str], extra: Optional[Dict] = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update({k: str(v) for k, v in extra.items()})
    if not merged:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus exposition-format snapshot of the whole registry."""
    lines: List[str] = []
    typed: Dict[str, str] = {}  # metric name -> emitted TYPE
    for kind, name, labels, obj in registry.items():
        if name not in typed:
            typed[name] = kind
            lines.append(f"# TYPE {name} {kind}")
        if kind in ("counter", "gauge"):
            lines.append(f"{name}{_fmt_labels(labels)} {_fmt_value(obj.value)}")
            continue
        # Histogram: cumulative le-buckets on the fixed grid.  Empty
        # buckets are elided (le series stays cumulative regardless).
        cum = 0
        for i, c in enumerate(obj.counts):
            cum += c
            if c == 0:
                continue
            le = _fmt_value(bucket_upper_ms(i))
            lines.append(
                f"{name}_bucket{_fmt_labels(labels, {'le': le})} {cum}"
            )
        lines.append(
            f"{name}_bucket{_fmt_labels(labels, {'le': '+Inf'})} {obj.count}"
        )
        lines.append(f"{name}_sum{_fmt_labels(labels)} {_fmt_value(obj.sum)}")
        lines.append(f"{name}_count{_fmt_labels(labels)} {obj.count}")
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, registry: MetricsRegistry) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(registry))


# -- JSONL span sink ---------------------------------------------------------
def write_jsonl_spans(path: str, source) -> None:
    with open(path, "w") as f:
        for s in _spans_of(source):
            f.write(json.dumps(s.to_dict()) + "\n")


def write_metrics_snapshot(path: str, registry: MetricsRegistry) -> None:
    with open(path, "w") as f:
        json.dump(registry.snapshot(), f, indent=1)


# -- conservation ------------------------------------------------------------
def request_conservation(source) -> Dict[str, int]:
    """Audit the request span trees: one terminal instant per root.

    Returns ``{"submitted", "resolved", "rejected", "cancelled", "open",
    "extra_terminals"}`` where ``open`` counts roots with *no* terminal
    and ``extra_terminals`` counts terminals beyond one per root.  A
    conserving trace has ``open == extra_terminals == 0`` and
    ``submitted == resolved + rejected + cancelled``.
    """
    spans = _spans_of(source)
    roots = [s for s in spans if s.name == "request"]
    terminals: Dict[int, List[str]] = {}
    for s in spans:
        if s.name in _TERMINAL_NAMES and s.parent_id is not None:
            terminals.setdefault(s.parent_id, []).append(s.name)
    counts = {"resolve": 0, "shed": 0, "cancel": 0}
    open_roots = 0
    extra = 0
    for r in roots:
        t = terminals.get(r.span_id, [])
        if not t:
            open_roots += 1
            continue
        counts[t[0]] += 1
        extra += len(t) - 1
    return {
        "submitted": len(roots),
        "resolved": counts["resolve"],
        "rejected": counts["shed"],
        "cancelled": counts["cancel"],
        "open": open_roots,
        "extra_terminals": extra,
    }


def iter_request_roots(source) -> Iterable[Span]:
    return (s for s in _spans_of(source) if s.name == "request")
