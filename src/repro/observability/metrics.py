"""Low-overhead metrics registry: counters, gauges, log-bucketed histograms.

Design constraints (the serving loop records on its hot path):

* **O(1) recording** — a histogram observation is one ``log10`` plus an
  integer bucket increment; counters and gauges are single float ops.  No
  sample lists are kept anywhere.
* **Fixed bucket layout** — every histogram shares one geometric grid:
  ``N_DECADES`` decades from ``BUCKET_LO_MS`` upward, ``PER_DECADE``
  buckets per decade (~1.21x per step), plus one overflow bucket —
  :data:`N_BUCKETS` (~O(100)) total.  Because the layout is global and
  static, any two snapshots are *mergeable* by elementwise addition
  (:meth:`HistogramSnapshot.merge`) — cross-replica and cross-run
  aggregation without resampling.
* **Percentile accessor** — :meth:`Histogram.percentile` interpolates
  linearly inside the winning bucket, the histogram analogue of the
  shared :func:`repro.observability.quantile.quantile` convention
  (resolution is the bucket width: ~±10%).

Metrics are identified by ``(name, labels)``; :class:`MetricsRegistry`
hands out get-or-create handles so instrumentation sites can call
``registry.counter("x", tenant="ui").inc()`` without caching anything.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "BUCKET_LO_MS",
    "PER_DECADE",
    "N_DECADES",
    "N_BUCKETS",
    "bucket_upper_ms",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramSnapshot",
    "MetricsRegistry",
]

# The shared histogram grid: 0.01 ms .. 1e6 ms (~17 min) in 12
# buckets/decade — 96 finite buckets + 1 overflow = 97 (~O(100)).
BUCKET_LO_MS = 1e-2
PER_DECADE = 12
N_DECADES = 8
N_BUCKETS = N_DECADES * PER_DECADE + 1  # finite grid + overflow

_LOG_LO = math.log10(BUCKET_LO_MS)


def bucket_index(value_ms: float) -> int:
    """O(1): which fixed bucket a value lands in (underflow → 0)."""
    if value_ms <= BUCKET_LO_MS:
        return 0
    idx = int((math.log10(value_ms) - _LOG_LO) * PER_DECADE)
    # A value exactly on a bucket edge belongs to the bucket above it in
    # float terms either way; clamp the top into the overflow bucket.
    return min(idx, N_BUCKETS - 1)


def bucket_upper_ms(index: int) -> float:
    """Upper bound of bucket ``index`` (inf for the overflow bucket)."""
    if index >= N_BUCKETS - 1:
        return math.inf
    return 10.0 ** (_LOG_LO + (index + 1) / PER_DECADE)


def bucket_lower_ms(index: int) -> float:
    if index <= 0:
        return 0.0
    return 10.0 ** (_LOG_LO + index / PER_DECADE)


class Counter:
    """Monotone accumulator."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


@dataclasses.dataclass(frozen=True)
class HistogramSnapshot:
    """Immutable histogram state; mergeable because the layout is fixed."""

    counts: Tuple[int, ...]
    count: int
    sum: float

    def merge(self, other: "HistogramSnapshot") -> "HistogramSnapshot":
        return HistogramSnapshot(
            counts=tuple(a + b for a, b in zip(self.counts, other.counts)),
            count=self.count + other.count,
            sum=self.sum + other.sum,
        )

    def percentile(self, q: float) -> float:
        return _percentile(self.counts, self.count, q)


def _percentile(counts, count: int, q: float) -> float:
    """Linear interpolation inside the winning bucket (NaN when empty)."""
    if count == 0:
        return float("nan")
    # The same rank convention as numpy's 'linear' method: the target
    # rank is q/100 * (n-1), counted over the ordered observations.
    rank = (q / 100.0) * (count - 1)
    seen = 0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if seen + c > rank:
            lo = bucket_lower_ms(i)
            hi = bucket_upper_ms(i)
            if math.isinf(hi):  # overflow bucket: its lower edge is honest
                return lo
            frac = (rank - seen + 0.5) / c  # midpoint-spread within bucket
            return lo + min(max(frac, 0.0), 1.0) * (hi - lo)
        seen += c
    return bucket_lower_ms(N_BUCKETS - 1)  # pragma: no cover - defensive


class Histogram:
    """Fixed-layout log-bucketed latency histogram (no sample list)."""

    __slots__ = ("counts", "count", "sum")

    def __init__(self):
        self.counts = [0] * N_BUCKETS
        self.count = 0
        self.sum = 0.0

    def record(self, value_ms: float) -> None:
        self.counts[bucket_index(value_ms)] += 1
        self.count += 1
        self.sum += value_ms

    def percentile(self, q: float) -> float:
        """Approximate percentile (bucket-resolution, ~±10%)."""
        return _percentile(self.counts, self.count, q)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def snapshot(self) -> HistogramSnapshot:
        return HistogramSnapshot(tuple(self.counts), self.count, self.sum)


LabelsKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, object]) -> LabelsKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Get-or-create handles for ``(name, labels)``-keyed metrics.

    Creation order is preserved (deterministic export); handle lookup is
    one dict get under a lock, and the returned objects are lock-free —
    all mutation happens on the serving loop's tick thread, matching the
    single-writer discipline the breakers already rely on.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str, LabelsKey], object] = {}

    def _get(self, kind: str, name: str, labels: Dict, factory):
        key = (kind, name, _labels_key(labels))
        with self._lock:
            obj = self._metrics.get(key)
            if obj is None:
                obj = self._metrics[key] = factory()
            return obj

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels, Histogram)

    # -- export surface --------------------------------------------------------
    def items(self) -> List[Tuple[str, str, Dict[str, str], object]]:
        """``(kind, name, labels, metric)`` in creation order."""
        with self._lock:
            return [
                (kind, name, dict(labels), obj)
                for (kind, name, labels), obj in self._metrics.items()
            ]

    def snapshot(self) -> Dict:
        """JSON-able point-in-time state (the metrics-snapshot export)."""
        out: Dict[str, List] = {"counters": [], "gauges": [], "histograms": []}
        for kind, name, labels, obj in self.items():
            if kind == "counter":
                out["counters"].append(
                    {"name": name, "labels": labels, "value": obj.value}
                )
            elif kind == "gauge":
                out["gauges"].append(
                    {"name": name, "labels": labels, "value": obj.value}
                )
            else:
                out["histograms"].append(
                    {
                        "name": name,
                        "labels": labels,
                        "count": obj.count,
                        "sum": obj.sum,
                        "counts": list(obj.counts),
                        "p50": obj.percentile(50),
                        "p99": obj.percentile(99),
                    }
                )
        return out

    def get_value(self, kind: str, name: str, **labels) -> Optional[float]:
        """Test/inspection helper: a metric's value, None if absent."""
        key = (kind, name, _labels_key(labels))
        with self._lock:
            obj = self._metrics.get(key)
        if obj is None:
            return None
        return obj.count if kind == "histogram" else obj.value
