"""Configs: the 10 assigned architectures + the paper's Table III zoo."""
from repro.configs.archs import ARCHS, ARCH_IDS, get_config, reduced
from repro.configs.mdinference_zoo import TABLE_III, ablation_zoo, paper_zoo
from repro.configs.shapes import SHAPES, applicable, input_specs, skip_reason

__all__ = [
    "ARCHS", "ARCH_IDS", "get_config", "reduced",
    "TABLE_III", "ablation_zoo", "paper_zoo",
    "SHAPES", "applicable", "input_specs", "skip_reason",
]
