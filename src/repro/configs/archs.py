"""The 10 assigned architectures as exact :class:`ModelConfig` instances.

Dims follow the assignment block verbatim; block-internal choices (rope
theta, norm styles, patterns) follow the cited sources.  ``reduced()``
shrinks any config to a CPU-smoke-test size of the same family.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "reduced", "ARCH_IDS"]


def _llama4_scout():
    # [moe] 48L d=5120 40H (kv=8) d_ff=8192 vocab=202048, 16 experts top-1,
    # shared expert (Llama-4 style), sigmoid router.
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        pattern=("moe",),
        n_experts=16,
        top_k=1,
        expert_d_ff=8192,
        n_shared_experts=1,
        router_type="sigmoid",
        rope_theta=500_000.0,
        tie_embeddings=False,
    )


def _olmoe():
    # [moe] 16L d=2048 16H d_ff=1024(expert) 64 experts top-8, qk-norm.
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        pattern=("moe",),
        n_experts=64,
        top_k=8,
        expert_d_ff=1024,
        qk_norm=True,
        rope_theta=10_000.0,
        tie_embeddings=False,
    )


def _recurrentgemma():
    # [hybrid] 26L d=2560 10H (kv=1, MQA) d_ff=7680 GeGLU, RG-LRU + local
    # attention (window 2048), 2 recurrent : 1 attention; 26 = 8*(r,r,a)+(r,r).
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        pattern=("recurrent", "recurrent", "local"),
        window=2048,
        lru_width=2560,
        mlp_type="geglu",
        emb_scale=True,
        norm_offset=True,
        tie_embeddings=True,
    )


def _xlstm():
    # [ssm] 24L d=1024 4H d_ff=0 — mLSTM blocks with 1 sLSTM per 8.
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab_size=50304,
        pattern=("mlstm",) * 7 + ("slstm",),
        xlstm_heads=4,
        xlstm_proj_factor=2.0,
        xlstm_chunk=64,
        tie_embeddings=True,
    )


def _gemma_2b():
    # [dense] 18L d=2048 8H (kv=1, MQA) d_ff=16384 GeGLU head_dim=256.
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256_000,
        pattern=("attn",),
        mlp_type="geglu",
        emb_scale=True,
        norm_offset=True,
        tie_embeddings=True,
    )


def _phi3_mini():
    # [dense] 32L d=3072 32H (kv=32, MHA) d_ff=8192 SwiGLU.
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        pattern=("attn",),
        rope_theta=10_000.0,
        tie_embeddings=False,
    )


def _qwen3_14b():
    # [dense] 40L d=5120 40H (kv=8) d_ff=17408, qk_norm.
    return ModelConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        pattern=("attn",),
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def _llama3_8b():
    # [dense] 32L d=4096 32H (kv=8) d_ff=14336 vocab=128256.
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        pattern=("attn",),
        rope_theta=500_000.0,
        tie_embeddings=False,
    )


def _hubert_xlarge():
    # [audio] 48L d=1280 16H d_ff=5120 encoder-only; conv feature extractor
    # is the modality stub (input_specs feeds 512-dim frame embeddings).
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        pattern=("attn",),
        mlp_type="gelu",
        causal=False,  # bidirectional encoder
        frontend="audio",
        frontend_dim=512,
        tie_embeddings=False,
    )


def _paligemma():
    # [vlm] gemma-2b text decoder + SigLIP patch stub (1152-d embeddings,
    # 256 patches) with prefix-LM masking over the image prefix.
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        pattern=("attn",),
        mlp_type="geglu",
        emb_scale=True,
        norm_offset=True,
        prefix_lm=True,
        frontend="vision",
        frontend_dim=1152,
        num_prefix_tokens=256,
        tie_embeddings=True,
    )


ARCHS = {
    c.name: c
    for c in (
        _llama4_scout(),
        _olmoe(),
        _recurrentgemma(),
        _xlstm(),
        _gemma_2b(),
        _phi3_mini(),
        _qwen3_14b(),
        _llama3_8b(),
        _hubert_xlarge(),
        _paligemma(),
    )
}
ARCH_IDS = tuple(ARCHS)


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    cfg = ARCHS[arch]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def reduced(arch: str, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    cfg = ARCHS[arch]
    pat_len = len(cfg.pattern)
    small = dict(
        n_layers=pat_len if pat_len > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else 0,
        lru_width=64 if cfg.lru_width else 0,
        expert_d_ff=64 if cfg.expert_d_ff else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        num_prefix_tokens=8 if cfg.num_prefix_tokens else 0,
        xlstm_chunk=8,
        attn_chunk=32,
        loss_chunk=32,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
