"""The paper's model zoo (Table III) + the on-device hedge-tier recipe.

Top-1 accuracy on ILSVRC-2012 and execution-latency statistics measured on
an AWS p2.xlarge GPU server over 1 000 runs (values transcribed from the
paper).  ``NasNet Fictional`` is the paper's synthetic low-accuracy copy of
NasNet Large, used *only* in the §VI-C stage ablation.

:data:`ONDEVICE_HEDGE` is the zoo's *executable* entry: the recipe for the
real tiny variant that plays the paper's on-device duplicate
(MobileNetV1_128 0.25, §V-B) in the serving stack.
``repro.serving.backend.OnDeviceBackend`` registers it so hedged requests
run on a second tier for real instead of sampling a latency profile.
"""
from __future__ import annotations

import dataclasses

from repro.core.registry import ModelProfile, ModelRegistry

__all__ = [
    "TABLE_III",
    "NASNET_FICTIONAL",
    "HedgeVariantSpec",
    "ONDEVICE_HEDGE",
    "ServingGeometry",
    "SERVING_GEOMETRY",
    "paper_zoo",
    "ablation_zoo",
]

TABLE_III: tuple[ModelProfile, ...] = (
    ModelProfile("SqueezeNet", 49.0, 4.91, 0.06),
    ModelProfile("MobileNetV1 0.25", 49.7, 3.21, 0.08),
    ModelProfile("MobileNetV1 0.5", 63.2, 4.21, 0.06),
    ModelProfile("DenseNet", 64.2, 25.49, 0.14),
    ModelProfile("MobileNetV1 0.75", 68.3, 4.67, 0.07),
    ModelProfile("MobileNetV1 1.0", 71.0, 5.43, 0.11),
    ModelProfile("NasNet Mobile", 73.9, 21.18, 0.17),
    ModelProfile("InceptionResNetV2", 77.5, 50.85, 0.33),
    ModelProfile("InceptionV3", 77.9, 31.11, 0.19),
    ModelProfile("InceptionV4", 80.1, 59.21, 0.22),
    ModelProfile("NasNet Large", 82.6, 112.61, 0.36),
)

NASNET_FICTIONAL = ModelProfile("NasNet Fictional", 50.0, 112.61, 0.36)


def paper_zoo() -> ModelRegistry:
    """The default cloud-side zoo (Table III without the fictional model)."""
    return ModelRegistry(TABLE_III)


def ablation_zoo() -> ModelRegistry:
    """Zoo for the §VI-C decomposition study (adds NasNet Fictional)."""
    return ModelRegistry(TABLE_III + (NASNET_FICTIONAL,))


@dataclasses.dataclass(frozen=True)
class HedgeVariantSpec:
    """Recipe for the real on-device hedge tier.

    The serving analogue of the paper's duplicate model: "most likely to
    complete within any SLA", so the smallest config we can build.  The
    quality score matches the paper's MobileNetV1_128 0.25 top-1 (41.4 %).
    """

    name: str = "hedge-xs (on-device)"
    arch: str = "gemma-2b"
    d_model: int = 32
    n_layers: int = 1
    n_heads: int = 2
    n_kv_heads: int = 1
    head_dim: int = 16
    quality: float = 41.4

    def config(self):
        """Materialize the tiny same-family :class:`ModelConfig`."""
        from repro.configs.archs import reduced

        return reduced(
            self.arch,
            d_model=self.d_model,
            n_layers=self.n_layers,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
        )


ONDEVICE_HEDGE = HedgeVariantSpec()


@dataclasses.dataclass(frozen=True)
class ServingGeometry:
    """Single source of truth for the serving tiers' cache geometry.

    Every shape the execution tiers compile against derives from here, so
    the batch-size ladder, the paged-cache page pool, and the dense ring
    caches cannot drift apart:

    * ``max_len`` — the dense tiers' (:class:`repro.serving.backend.JitBackend`
      / :class:`~repro.serving.backend.OnDeviceBackend`) ring-cache length;
      the historical hardcoded 256.
    * ``prompt_width`` — the continuous tier's *fixed* prefill width.  All
      prompts are right-padded to exactly this many tokens, so one prefill
      executable per ladder batch size covers every request shape.
    * ``bs_ladder`` — the power-of-two prefill batch sizes that get a
      pre-compiled ``prefill_bs{N}`` entry point each.
    * ``n_slots`` — width of the persistent decode batch (the single
      fixed-shape ``decode`` executable).
    * ``page_size`` / ``n_pages`` — the block-paged KV cache: page 0 is the
      reserved trash page inactive rows write into; ``None`` sizes the pool
      so every slot can hold a full request
      (``1 + n_slots * ceil((prompt_width + max_steps) / page_size)``).
    * ``max_steps`` — per-request decode-step cap on the continuous tier.
    """

    max_len: int = 256
    prompt_width: int = 32
    bs_ladder: tuple[int, ...] = (1, 2, 4, 8)
    n_slots: int = 8
    page_size: int = 8
    n_pages: int | None = None
    max_steps: int = 32

    def __post_init__(self):
        if any(n & (n - 1) for n in self.bs_ladder) or not self.bs_ladder:
            raise ValueError(f"bs_ladder must be powers of two: {self.bs_ladder}")
        if tuple(sorted(self.bs_ladder)) != tuple(self.bs_ladder):
            raise ValueError(f"bs_ladder must be sorted: {self.bs_ladder}")
        if self.prompt_width % self.page_size:
            raise ValueError(
                f"prompt_width ({self.prompt_width}) must be a multiple of "
                f"page_size ({self.page_size})"
            )

    @property
    def pages_per_slot(self) -> int:
        """Worst-case pages one slot can reserve (full prompt + max steps)."""
        need = self.prompt_width + self.max_steps
        return -(-need // self.page_size)

    @property
    def total_pages(self) -> int:
        """Physical page-pool size: the trash page + every slot full."""
        if self.n_pages is not None:
            return self.n_pages
        return 1 + self.n_slots * self.pages_per_slot


SERVING_GEOMETRY = ServingGeometry()
