"""The paper's model zoo (Table III).

Top-1 accuracy on ILSVRC-2012 and execution-latency statistics measured on
an AWS p2.xlarge GPU server over 1 000 runs (values transcribed from the
paper).  ``NasNet Fictional`` is the paper's synthetic low-accuracy copy of
NasNet Large, used *only* in the §VI-C stage ablation.
"""
from __future__ import annotations

from repro.core.registry import ModelProfile, ModelRegistry

__all__ = [
    "TABLE_III",
    "NASNET_FICTIONAL",
    "paper_zoo",
    "ablation_zoo",
]

TABLE_III: tuple[ModelProfile, ...] = (
    ModelProfile("SqueezeNet", 49.0, 4.91, 0.06),
    ModelProfile("MobileNetV1 0.25", 49.7, 3.21, 0.08),
    ModelProfile("MobileNetV1 0.5", 63.2, 4.21, 0.06),
    ModelProfile("DenseNet", 64.2, 25.49, 0.14),
    ModelProfile("MobileNetV1 0.75", 68.3, 4.67, 0.07),
    ModelProfile("MobileNetV1 1.0", 71.0, 5.43, 0.11),
    ModelProfile("NasNet Mobile", 73.9, 21.18, 0.17),
    ModelProfile("InceptionResNetV2", 77.5, 50.85, 0.33),
    ModelProfile("InceptionV3", 77.9, 31.11, 0.19),
    ModelProfile("InceptionV4", 80.1, 59.21, 0.22),
    ModelProfile("NasNet Large", 82.6, 112.61, 0.36),
)

NASNET_FICTIONAL = ModelProfile("NasNet Fictional", 50.0, 112.61, 0.36)


def paper_zoo() -> ModelRegistry:
    """The default cloud-side zoo (Table III without the fictional model)."""
    return ModelRegistry(TABLE_III)


def ablation_zoo() -> ModelRegistry:
    """Zoo for the §VI-C decomposition study (adds NasNet Fictional)."""
    return ModelRegistry(TABLE_III + (NASNET_FICTIONAL,))
