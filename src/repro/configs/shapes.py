"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four shape cells per architecture:
  train_4k     seq 4096,   global batch 256  -> train_step
  prefill_32k  seq 32768,  global batch 32   -> prefill (serve_step)
  decode_32k   KV 32768,   global batch 128  -> decode  (serve_step)
  long_500k    KV 524288,  global batch 1    -> decode, sub-quadratic only

Applicability (DESIGN.md §Arch-applicability): encoder-only archs have no
decode step; ``long_500k`` requires O(1)/O(window) decode state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.archs import get_config
from repro.models import transformer
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "ShapeCell", "applicable", "input_specs", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    cell = SHAPES[shape]
    if cell.kind == "decode" and cfg.encoder_only:
        return "encoder-only architecture has no decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return "full-attention KV state is quadratic-cost at 500k; skipped per assignment"
    return None


def applicable(cfg: ModelConfig, shape: str) -> bool:
    return skip_reason(cfg, shape) is None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str, *, batch_override: int = None):
    """ShapeDtypeStruct inputs for (arch, shape) — no device allocation.

    Returns a dict:
      train:   {"inputs": {tokens/frames/patches, labels}}
      prefill: {"inputs": {...}}
      decode:  {"cache": <pytree>, "token": (B,), "pos": (B,)}
    """
    cell = SHAPES[shape]
    B = batch_override or cell.global_batch
    S = cell.seq_len
    i32, f32 = jnp.int32, jnp.float32

    if cell.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            inputs = {"frames": _sds((B, S, cfg.frontend_dim), f32)}
            if cell.kind == "train":
                inputs["labels"] = _sds((B, S), i32)
        elif cfg.frontend == "vision":
            P = cfg.num_prefix_tokens
            inputs = {
                "patches": _sds((B, P, cfg.frontend_dim), f32),
                "tokens": _sds((B, S - P), i32),
            }
            if cell.kind == "train":
                inputs["labels"] = _sds((B, S - P), i32)
        else:
            inputs = {"tokens": _sds((B, S), i32)}
            if cell.kind == "train":
                inputs["labels"] = _sds((B, S), i32)
        return {"inputs": inputs}

    # decode: cache shapes from init_cache under eval_shape (no allocation).
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, B, S))
    return {
        "cache": cache,
        "token": _sds((B,), i32),
        "pos": _sds((B,), i32),
    }
