"""Model assembly: heterogeneous block stacks, init, train/prefill/decode.

Parameters are built from *spec tables* — ``{name: (shape, logical_axes)}`` —
so the parameter tree and its sharding spec tree are generated from the same
source and cannot drift.  Layers are stacked per *pattern period* and run
under ``lax.scan`` (O(1) HLO size; remat per period during training).
Depths not divisible by the period length get an explicit unstacked epilogue.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.api import constrain
from repro.models import layers, moe, rglru, xlstm
from repro.models.attention import (
    decode_attention,
    flash_attention,
    paged_decode_attention,
)
from repro.models.config import ModelConfig

__all__ = [
    "init_params",
    "param_axes",
    "forward_hidden",
    "loss_fn",
    "init_cache",
    "prefill",
    "prefill_ragged",
    "decode_step",
    "init_paged_cache",
    "graft_prefill",
    "graft_prefill_batch",
    "paged_decode_step",
    "supports_paged_decode",
    "SeqContext",
]


# ---------------------------------------------------------------------------
# Spec tables.
# ---------------------------------------------------------------------------
def _attn_spec(cfg: ModelConfig):
    d, hd, nq, nkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    spec = {
        "wq": ((d, nq * hd), ("embed", "heads")),
        "wk": ((d, nkv * hd), ("embed", "kv_heads")),
        "wv": ((d, nkv * hd), ("embed", "kv_heads")),
        "wo": ((nq * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = ((hd,), (None,))
        spec["k_norm"] = ((hd,), (None,))
    return spec


def block_spec(cfg: ModelConfig, kind: str):
    d = cfg.d_model
    ln = ((d,), ("embed",))
    if kind in ("attn", "local"):
        return {
            "ln1": ln,
            "attn": _attn_spec(cfg),
            "ln2": ln,
            "mlp": layers.mlp_init_spec(d, cfg.d_ff, cfg.mlp_type),
        }
    if kind == "moe":
        return {
            "ln1": ln,
            "attn": _attn_spec(cfg),
            "ln2": ln,
            "moe": moe.moe_init_spec(cfg),
        }
    if kind == "recurrent":
        return {
            "ln1": ln,
            "rec": rglru.rglru_init_spec(cfg),
            "ln2": ln,
            "mlp": layers.mlp_init_spec(d, cfg.d_ff, cfg.mlp_type),
        }
    if kind == "mlstm":
        return {"ln1": ln, "cell": xlstm.mlstm_init_spec(cfg)}
    if kind == "slstm":
        return {"ln1": ln, "cell": xlstm.slstm_init_spec(cfg)}
    raise ValueError(kind)


def model_spec(cfg: ModelConfig):
    d = cfg.d_model
    spec: Dict[str, Any] = {
        # Replicated over the tensor axis, FSDP on d_model: token gathers are
        # then fully local (a vocab-sharded table makes GSPMD replicate the
        # whole table inside the gather — measured on the multi-pod mesh).
        "embed": {"tokens": ((cfg.vocab_size, d), (None, "embed"))},
        "final_norm": ((d,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        # Tiny classification vocabularies (HuBERT: 504) are replicated —
        # not divisible by the tensor axis, and too small to matter.
        v_ax = "vocab" if cfg.vocab_size >= 1024 else None
        spec["head"] = ((d, cfg.vocab_size), ("embed", v_ax))
    if cfg.frontend != "none":
        spec["frontend"] = {
            "proj": ((cfg.frontend_dim, d), (None, "embed")),
        }
    spec["periods"] = tuple(block_spec(cfg, k) for k in cfg.pattern)
    spec["epilogue"] = tuple(block_spec(cfg, k) for k in cfg.epilogue)
    return spec


# ---------------------------------------------------------------------------
# Init from spec.
# ---------------------------------------------------------------------------
def _init_leaf(key, name: str, shape, dtype, norm_offset: bool):
    if name.startswith("ln") or name.endswith("_norm") or name == "final_norm":
        fill = 0.0 if norm_offset else 1.0
        return jnp.full(shape, fill, dtype)
    if name == "lamb":  # RG-LRU decay: a ~ 0.95 at sigmoid midpoint
        return jnp.full(shape, 0.65, dtype)
    if name in ("bf",):  # forget-gate bias: remember by default
        return jnp.full(shape, 1.0, dtype)
    if name.endswith("_b") or name in ("bi", "bz", "bo") or name.startswith("b"):
        return jnp.zeros(shape, dtype)
    return layers.truncated_normal_init(key, shape, dtype, 1.0)


def _is_leaf_spec(node):
    return (
        isinstance(node, tuple)
        and len(node) == 2
        and isinstance(node[0], tuple)
        and all(isinstance(s, int) for s in node[0])
    )


def _walk_spec(spec, fn, path=()):  # fn(path, (shape, axes)) -> leaf value
    if _is_leaf_spec(spec):
        return fn(path, spec)
    if isinstance(spec, dict):
        return {k: _walk_spec(v, fn, path + (k,)) for k, v in spec.items()}
    if isinstance(spec, tuple):
        return tuple(_walk_spec(v, fn, path + (str(i),)) for i, v in enumerate(spec))
    raise TypeError(f"bad spec node at {path}: {type(spec)}")


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    n_p = cfg.n_periods

    def init(path, leaf):
        shape, _ = leaf
        name = path[-1]
        k = jax.random.fold_in(key, zlib.crc32("/".join(path).encode()))
        stacked = path[0] == "periods"
        full_shape = (n_p, *shape) if stacked else shape
        ldtype = jnp.float32 if _fp32_leaf(name) else dtype
        return _init_leaf(k, name, full_shape, ldtype, cfg.norm_offset)

    return _walk_spec(model_spec(cfg), init)


def _fp32_leaf(name: str) -> bool:
    """Norms/gate biases/decays stay fp32 for stability."""
    return (
        name.startswith("ln")
        or name.endswith("_norm")
        or name == "final_norm"
        or name in ("lamb", "bi", "bf", "gate_a_b", "gate_x_b")
    )


def param_axes(cfg: ModelConfig):
    """Pytree matching init_params with logical-axis tuples as leaves."""

    def axes(path, leaf):
        _, ax = leaf
        if path[0] == "periods":
            return (None, *ax)  # stacking axis is unsharded
        return tuple(ax)

    return _walk_spec(model_spec(cfg), axes)


# ---------------------------------------------------------------------------
# Block application.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SeqContext:
    positions: jax.Array  # (B, S) int32 absolute positions
    prefix_len: Optional[jax.Array] = None  # (B,) prefix-LM boundary
    decode: bool = False
    # Block-paged decode (continuous batching): per-row page tables into a
    # shared physical KV pool.  None => the dense ring-buffer cache path.
    page_tables: Optional[jax.Array] = None  # (B, NB) int32 page ids
    page_size: int = 0


def _norm(cfg, w, x):
    return layers.rms_norm(x, w, eps=cfg.norm_eps, offset=cfg.norm_offset)


def _kv_quant(x):
    """(…, HD) -> int8 values + per-(entry, head) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _attention(cfg, p, x, ctx: SeqContext, kind: str, cache):
    B, S, _ = x.shape
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    # Constrain the flattened head dim (always divisible by the tensor axis)
    # and let GSPMD propagate through the reshape — constraining the 4D
    # (B, S, H, HD) layout pads H up to the axis size for H < 16 archs.
    q = constrain(x @ p["wq"], "batch", "seq", "heads").reshape(B, S, nq, hd)
    k = constrain(x @ p["wk"], "batch", "seq", "kv_heads").reshape(B, S, nkv, hd)
    v = constrain(x @ p["wv"], "batch", "seq", "kv_heads").reshape(B, S, nkv, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], eps=cfg.norm_eps)
    sin, cos = layers.rope(ctx.positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, sin, cos)
    k = layers.apply_rope(k, sin, cos)
    window = cfg.window if kind == "local" else 0

    if ctx.decode:
        assert cache is not None and S == 1
        # Flash-decode ("split-S") layout: q is tiny — replicate it across
        # the tensor axis and let every device attend over its *sequence*
        # shard of the cache; the output combine is a (B, NQ*HD) all-reduce
        # (KBs).  Keeping q head-sharded instead makes the einsum partition
        # by (padded) KV heads and gather the whole cache (250 MiB/layer
        # measured).
        q = constrain(q.reshape(B, S, -1), "batch", "seq", None).reshape(
            B, S, nq, hd
        )
        # Same for the new k/v: head-sharded single-token projections would
        # re-shard the whole cache on write (the head_dim all-gather below
        # was measured at 8 GiB/step).
        k = constrain(k.reshape(B, S, -1), "batch", "seq", None).reshape(
            B, S, nkv, hd
        )
        v = constrain(v.reshape(B, S, -1), "batch", "seq", None).reshape(
            B, S, nkv, hd
        )
        pos = ctx.positions[:, 0]  # (B,)
        if ctx.page_tables is not None:
            # Block-paged decode (continuous batching): rows advance at
            # *independent* positions, each writing into its own page-table
            # slot of the shared pool.  The per-row scatter is fine here —
            # this path serves the single-host continuous tier, where the
            # pool is unsharded (the lockstep dynamic-update-slice below
            # exists for the seq-sharded multi-pod caches).  Inactive rows
            # carry pos=0 and an all-trash table, so their writes land in
            # the reserved trash page.
            page = ctx.page_size
            P = cache["kp"].shape[0]
            tbl = ctx.page_tables  # (B, NB)
            flat_idx = (
                tbl[jnp.arange(B), pos // page] * page + pos % page
            )  # (B,)
            kf = cache["kp"].reshape(P * page, nkv, hd).at[flat_idx].set(k[:, 0])
            vf = cache["vp"].reshape(P * page, nkv, hd).at[flat_idx].set(v[:, 0])
            kp = kf.reshape(P, page, nkv, hd)
            vp = vf.reshape(P, page, nkv, hd)
            out = paged_decode_attention(q, kp, vp, tbl, pos, window=window)
            new_cache = {"kp": kp, "vp": vp}
            out = constrain(out.reshape(B, S, nq * hd), "batch", "seq", "heads")
            return out @ p["wo"], new_cache
        # Aligned decoding: all rows advance in lockstep (continuous batching
        # buckets by position at the engine layer), so the ring-buffer write
        # is one dynamic-update-slice at a shared slot — a per-row scatter
        # onto the seq-sharded cache makes GSPMD gather whole cache shards
        # (measured: 8 GiB/step of all-gather on llama3 decode_32k).
        slot = pos[0] % cache["k"].shape[1]
        if cfg.kv_cache_quant:
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
            kss = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, slot, axis=1)
            vss = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, slot, axis=1)
            sp = jax.lax.dynamic_update_slice_in_dim(
                cache["slot_pos"], pos[:, None], slot, axis=1
            )
            out = decode_attention(
                q,
                _kv_dequant(kc, kss, k.dtype),
                _kv_dequant(vc, vss, v.dtype),
                sp, pos, window=window,
            )
            new_cache = {"k": kc, "v": vc, "slot_pos": sp,
                         "k_scale": kss, "v_scale": vss}
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
            sp = jax.lax.dynamic_update_slice_in_dim(
                cache["slot_pos"], pos[:, None], slot, axis=1
            )
            out = decode_attention(q, kc, vc, sp, pos, window=window)
            new_cache = {"k": kc, "v": vc, "slot_pos": sp}
    else:
        out = flash_attention(
            q,
            k,
            v,
            causal=cfg.causal,
            window=window,
            prefix_len=ctx.prefix_len,
            chunk=cfg.attn_chunk,
            unroll=cfg.unroll_scans,
        )
        new_cache = None
        if cache is not None:
            # Prefill cache write.  Prompt positions are static (0..S-1), so
            # the ring-buffer write is one or two STATIC slice updates — a
            # dynamic scatter here trips GSPMD's full-replication fallback
            # (measured: +50 GiB/device on 32k prefill cells).
            sc = cache["k"].shape[1]
            keep = min(S, sc)
            start = S - keep  # first kept prompt position
            slot0 = start % sc
            kc, vc, sp = cache["k"], cache["v"], cache["slot_pos"]
            if cfg.kv_cache_quant:
                kw, ksw = _kv_quant(k)
                vw, vsw = _kv_quant(v)
                kss, vss = cache["k_scale"], cache["v_scale"]
            else:
                kw, vw = k, v
            pos_tail = ctx.positions[:, start:]  # (B, keep)
            first = min(keep, sc - slot0)
            kc = kc.at[:, slot0 : slot0 + first].set(kw[:, start : start + first])
            vc = vc.at[:, slot0 : slot0 + first].set(vw[:, start : start + first])
            sp = sp.at[:, slot0 : slot0 + first].set(pos_tail[:, :first])
            if cfg.kv_cache_quant:
                kss = kss.at[:, slot0 : slot0 + first].set(ksw[:, start : start + first])
                vss = vss.at[:, slot0 : slot0 + first].set(vsw[:, start : start + first])
            if keep > first:  # wrapped remainder
                rest = keep - first
                kc = kc.at[:, :rest].set(kw[:, start + first :])
                vc = vc.at[:, :rest].set(vw[:, start + first :])
                sp = sp.at[:, :rest].set(pos_tail[:, first:])
                if cfg.kv_cache_quant:
                    kss = kss.at[:, :rest].set(ksw[:, start + first :])
                    vss = vss.at[:, :rest].set(vsw[:, start + first :])
            new_cache = {"k": kc, "v": vc, "slot_pos": sp}
            if cfg.kv_cache_quant:
                new_cache.update({"k_scale": kss, "v_scale": vss})

    out = constrain(out.reshape(B, S, nq * hd), "batch", "seq", "heads")
    return out @ p["wo"], new_cache


def apply_block(cfg, kind: str, p, x, ctx: SeqContext, cache):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("attn", "local", "moe"):
        h, attn_cache = _attention(cfg, p["attn"], _norm(cfg, p["ln1"], x), ctx, kind, cache)
        x = x + h
        h2 = _norm(cfg, p["ln2"], x)
        if kind == "moe":
            y, aux = moe.moe_apply(cfg, p["moe"], h2)
        else:
            y = layers.mlp_apply(p["mlp"], h2, cfg.mlp_type)
        x = x + y
        return x, attn_cache, aux
    if kind == "recurrent":
        h = _norm(cfg, p["ln1"], x)
        if ctx.decode:
            y, new_cache = rglru.rglru_decode_step(cfg, p["rec"], h, cache)
        else:
            h0 = cache["h"] if cache is not None else None
            tail = cache["conv_tail"] if cache is not None else None
            y, (h_last, new_tail) = rglru.rglru_apply(cfg, p["rec"], h, h0=h0, conv_tail=tail)
            new_cache = {"h": h_last, "conv_tail": new_tail} if cache is not None else None
        x = x + y
        y2 = layers.mlp_apply(p["mlp"], _norm(cfg, p["ln2"], x), cfg.mlp_type)
        return x + y2, new_cache, aux
    if kind == "mlstm":
        h = _norm(cfg, p["ln1"], x)
        if ctx.decode:
            y, new_cache = xlstm.mlstm_decode_step(cfg, p["cell"], h, cache)
        else:
            carry = (cache["C"], cache["n"]) if cache is not None else None
            y, (C, n) = xlstm.mlstm_apply(cfg, p["cell"], h, carry=carry)
            new_cache = {"C": C, "n": n} if cache is not None else None
        return x + y, new_cache, aux
    if kind == "slstm":
        h = _norm(cfg, p["ln1"], x)
        if ctx.decode:
            y, new_cache = xlstm.slstm_decode_step(cfg, p["cell"], h, cache)
        else:
            y, state = xlstm.slstm_apply(cfg, p["cell"], h, state=cache)
            new_cache = state if cache is not None else None
        return x + y, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------
def _block_cache(cfg, kind, batch, max_len, dtype):
    if kind in ("attn", "moe", "local"):
        sc = max_len if kind != "local" else min(cfg.window, max_len)
        kv_dt = jnp.int8 if cfg.kv_cache_quant else dtype
        cache = {
            "k": jnp.zeros((batch, sc, cfg.n_kv_heads, cfg.head_dim), kv_dt),
            "v": jnp.zeros((batch, sc, cfg.n_kv_heads, cfg.head_dim), kv_dt),
            "slot_pos": jnp.full((batch, sc), -1, jnp.int32),
        }
        if cfg.kv_cache_quant:
            cache["k_scale"] = jnp.zeros((batch, sc, cfg.n_kv_heads), jnp.float32)
            cache["v_scale"] = jnp.zeros((batch, sc, cfg.n_kv_heads), jnp.float32)
        return cache
    if kind == "recurrent":
        return rglru.rglru_init_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.mlstm_init_cache(cfg, batch)
    if kind == "slstm":
        return xlstm.slstm_init_cache(cfg, batch)
    raise ValueError(kind)


def _stack_cache(cache, n):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n, *a.shape)).copy(), cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    periods = tuple(
        _stack_cache(_block_cache(cfg, k, batch, max_len, dtype), cfg.n_periods)
        for k in cfg.pattern
    )
    epilogue = tuple(
        _block_cache(cfg, k, batch, max_len, dtype) for k in cfg.epilogue
    )
    return {"periods": periods, "epilogue": epilogue}


def _block_cache_axes(cfg, kind, stacked: bool):
    """Logical axes per block-kind cache (mirrors _block_cache).

    KV caches shard their *sequence* dim on the tensor axis ("seq_kv") —
    with GQA/MQA there are fewer KV heads than tensor shards, and the cache
    (not the weights) dominates decode memory, so sequence-sharding the
    cache is what makes decode_32k/long_500k fit.
    """
    pre = (None,) if stacked else ()
    if kind in ("attn", "moe", "local"):
        ax = {
            "k": pre + ("batch", "seq_kv", None, None),
            "v": pre + ("batch", "seq_kv", None, None),
            "slot_pos": pre + ("batch", "seq_kv"),
        }
        if cfg.kv_cache_quant:
            ax["k_scale"] = pre + ("batch", "seq_kv", None)
            ax["v_scale"] = pre + ("batch", "seq_kv", None)
        return ax
    if kind == "recurrent":
        return {
            "h": pre + ("batch", "lru"),
            "conv_tail": pre + ("batch", None, "lru"),
        }
    if kind == "mlstm":
        return {
            "C": pre + ("batch", None, None, None),
            "n": pre + ("batch", None, None),
        }
    if kind == "slstm":
        return {k: pre + ("batch", "lru") for k in ("c", "n", "h", "m")}
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig):
    """Logical axes for the cache pytree (for sharding at the launcher)."""
    return {
        "periods": tuple(
            _block_cache_axes(cfg, k, stacked=True) for k in cfg.pattern
        ),
        "epilogue": tuple(
            _block_cache_axes(cfg, k, stacked=False) for k in cfg.epilogue
        ),
    }


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------
def _embed_inputs(cfg, params, batch_inputs):
    """-> (x (B,S,D), positions (B,S), prefix_len or None, labels or None)."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        frames = batch_inputs["frames"]  # (B, S, frontend_dim)
        x = (frames.astype(dtype) @ params["frontend"]["proj"]).astype(dtype)
        B, S = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return x, pos, None, batch_inputs.get("labels")
    tokens = batch_inputs["tokens"]
    x = jnp.take(params["embed"]["tokens"], tokens, axis=0).astype(dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    prefix_len = None
    if cfg.frontend == "vision" and "patches" in batch_inputs:  # absent at decode
        patches = batch_inputs["patches"]  # (B, P, frontend_dim)
        pe = (patches.astype(dtype) @ params["frontend"]["proj"]).astype(dtype)
        if cfg.emb_scale:
            pe = pe * jnp.asarray(np.sqrt(cfg.d_model), dtype)
        x = jnp.concatenate([pe, x], axis=1)
        P = patches.shape[1]
        prefix_len = jnp.full((x.shape[0],), P, jnp.int32)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, pos, prefix_len, batch_inputs.get("labels")


def _run_stack(cfg, params, x, ctx: SeqContext, cache=None, collect_cache=False):
    """Scan over periods (+ epilogue).  Returns (x, new_cache, aux)."""
    n_p = cfg.n_periods
    use_cache = cache is not None

    def period_fn(x, period_params, period_caches):
        aux = jnp.float32(0.0)
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            c = period_caches[i] if use_cache else None
            x, nc, a = apply_block(cfg, kind, period_params[i], x, ctx, c)
            aux = aux + a
            new_caches.append(nc)
        if not ctx.decode:
            # Sequence-parallel boundary: no-op under the baseline rules
            # (seq_act -> None); under RULES_*_SP shards the residual stream
            # (and the scan carry) over the tensor axis.
            x = constrain(x, "batch", "seq_act", None)
        else:
            # Weight-stationary decode boundary (RULES_*_DEC): no-op under
            # the baseline.
            x = constrain(x, "batch", None, "embed_act")
        return x, tuple(new_caches), aux

    if cfg.remat and not ctx.decode and not use_cache:
        period_fn = jax.checkpoint(period_fn)

    if cfg.scan_layers and n_p > 0:
        def body(carry, xs):
            x, aux = carry
            pp = xs[0]
            pc = xs[1] if use_cache else None
            x, ncs, a = period_fn(x, pp, pc)
            ys = ncs if (use_cache or collect_cache) else None
            return (x, aux + a), ys

        xs = (params["periods"], cache["periods"]) if use_cache else (params["periods"], None)
        (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
        new_periods = ys
    else:
        aux = jnp.float32(0.0)
        new_periods_list = []
        for li in range(n_p):
            pp = jax.tree.map(lambda a: a[li], params["periods"])
            pc = jax.tree.map(lambda a: a[li], cache["periods"]) if use_cache else None
            x, ncs, a = period_fn(x, pp, pc)
            aux = aux + a
            new_periods_list.append(ncs)
        if use_cache and n_p > 0:
            new_periods = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_periods_list
            )
        else:
            new_periods = None

    new_epilogue = []
    for i, kind in enumerate(cfg.epilogue):
        c = cache["epilogue"][i] if use_cache else None
        x, nc, a = apply_block(cfg, kind, params["epilogue"][i], x, ctx, c)
        aux = aux + a
        new_epilogue.append(nc)

    new_cache = (
        {"periods": new_periods, "epilogue": tuple(new_epilogue)} if use_cache else None
    )
    return x, new_cache, aux


def forward_hidden(cfg, params, batch_inputs, cache=None, decode=False, positions=None,
                   page_tables=None, page_size=0):
    x, pos, prefix_len, _ = _embed_inputs(cfg, params, batch_inputs)
    if positions is not None:
        pos = positions
    ctx = SeqContext(positions=pos, prefix_len=prefix_len, decode=decode,
                     page_tables=page_tables, page_size=page_size)
    x = constrain(x, "batch", "seq_act" if not decode else "seq", None)
    x, new_cache, aux = _run_stack(cfg, params, x, ctx, cache=cache)
    x = _norm(cfg, params["final_norm"], x)
    return x, new_cache, aux


def _head_weight(cfg, params):
    """(D, V) head weight, re-sharded once: vocab-TP, embed gathered.

    Gathering the head tile beats letting GSPMD all-reduce full (B, S, V)
    logits (measured: 12 GB/device/step of avoidable all-reduce on
    256k-vocab archs).  Callers hoist this out of the loss chunk loop.
    """
    w = params["embed"]["tokens"].T if cfg.tie_embeddings else params["head"]
    return constrain(w, None, "vocab" if cfg.vocab_size >= 1024 else None)


def _unembed(cfg, params, x, w=None):
    if w is None:
        w = _head_weight(cfg, params)
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return constrain(logits, "batch", "seq", "vocab")


def loss_fn(cfg, params, batch):
    """Chunked softmax-xent.  batch: inputs dict with 'labels' (B, S_out).

    labels < 0 are ignored (prefix/padding).  Returns (loss, metrics).
    """
    x, _, aux = forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    B, S = labels.shape
    x = x[:, -S:]  # align (vision prefix may extend the hidden sequence)
    C = min(cfg.loss_chunk, S)
    while S % C:
        C -= 1
    nc = S // C

    w_head = _head_weight(cfg, params)

    def body(carry, i):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, i * C, C, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * C, C, axis=1)
        logits = _unembed(cfg, params, xs, w_head)
        lp = jax.nn.log_softmax(logits, axis=-1)
        valid = ls >= 0
        nll = -jnp.take_along_axis(lp, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum(nll * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(nc),
        unroll=cfg.unroll_scans,
    )
    xent = tot / jnp.maximum(cnt, 1.0)
    loss = xent + 0.01 * aux
    return loss, {"xent": xent, "aux": aux, "tokens": cnt}


def prefill(cfg, params, batch_inputs, max_len: int):
    """Run the prompt, returning (cache, last-position logits)."""
    tokens_like = batch_inputs.get("tokens", batch_inputs.get("frames"))
    B = tokens_like.shape[0]
    cache = init_cache(cfg, B, max_len)
    x, cache, _ = forward_hidden(cfg, params, batch_inputs, cache=cache)
    logits = _unembed(cfg, params, x[:, -1:])
    return cache, logits[:, 0]


def decode_step(cfg, params, cache, token, pos):
    """One decode step.  token: (B,) int32; pos: (B,) int32 positions."""
    inputs = {"tokens": token[:, None]}
    x, cache, _ = forward_hidden(
        cfg, params, inputs, cache=cache, decode=True, positions=pos[:, None]
    )
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# Block-paged decode (continuous batching).
# ---------------------------------------------------------------------------
def supports_paged_decode(cfg: ModelConfig) -> bool:
    """Whether the paged continuous-batching path can serve this config.

    Requires an attention-only causal stack without KV-cache quantization:
    recurrent/xLSTM states are not paged, and the paged layout stores
    full-precision K/V (the continuous tier's pools are small).
    """
    kinds = tuple(cfg.pattern) + tuple(cfg.epilogue)
    return (
        cfg.causal
        and not cfg.kv_cache_quant
        and all(k in ("attn", "local", "moe") for k in kinds)
    )


def prefill_ragged(cfg, params, batch_inputs, lengths, max_len: int):
    """Prefill a right-padded batch with per-row prompt lengths.

    Fixed-shape companion to :func:`prefill`: ``tokens`` is always
    ``(B, max_len)`` wide (right-padded), ``lengths`` gives each row's real
    prompt length, and the returned logits are gathered at each row's last
    *real* position.  Causal masking makes right-padding inert — position
    ``L-1`` never attends positions ``>= L`` — so the logits equal the
    unpadded prefill's.  Cache entries past a row's length hold pad-token
    garbage; the paged graft relies on the append-only mask (and subsequent
    decode writes) to keep it unread.
    """
    tokens = batch_inputs["tokens"]
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x, cache, _ = forward_hidden(cfg, params, batch_inputs, cache=cache)
    idx = jnp.clip(lengths - 1, 0, S - 1)
    x_last = x[jnp.arange(B), idx][:, None]  # (B, 1, D)
    logits = _unembed(cfg, params, x_last)[:, 0]
    return cache, logits


def _paged_block_cache(cfg, kind, n_pages, page_size, dtype):
    if kind not in ("attn", "moe", "local"):
        raise ValueError(
            f"paged decode supports attention blocks only, got {kind!r}"
        )
    return {
        "kp": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim), dtype),
        "vp": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int):
    """Physical KV page pools for every attention layer (no batch dim —
    rows share the pool through their page tables)."""
    if not supports_paged_decode(cfg):
        raise ValueError(
            f"config {cfg.name!r} cannot use the paged decode path "
            "(needs a causal attention-only stack without kv quant)"
        )
    dtype = jnp.dtype(cfg.dtype)
    periods = tuple(
        _stack_cache(
            _paged_block_cache(cfg, k, n_pages, page_size, dtype), cfg.n_periods
        )
        for k in cfg.pattern
    )
    epilogue = tuple(
        _paged_block_cache(cfg, k, n_pages, page_size, dtype)
        for k in cfg.epilogue
    )
    return {"periods": periods, "epilogue": epilogue}


def graft_prefill(cfg, paged_cache, prefill_cache, row, page_table, page_size: int):
    """Copy one prefilled row's KV state into its slot's pages.

    ``prefill_cache`` comes from :func:`prefill_ragged` over a cache of
    exactly the prompt width ``W`` (positions ``0..W-1``, no ring wrap, so
    dense index == absolute position).  All ``W`` positions are scattered
    through the page table: positions past the row's reservation land in
    the trash page, positions between the row's real length and ``W`` are
    pad garbage that decode overwrites in place before the mask ever
    exposes them.  Fixed shapes throughout — one compile per prefill batch
    size.
    """
    idx = jnp.arange(prefill_cache_width(prefill_cache))
    flat_idx = page_table[idx // page_size] * page_size + idx % page_size

    def graft_leaves(pool, pre):
        # pool: (*lead, P, page, NKV, HD); pre: (*lead, B, W, NKV, HD)
        def one(pool_leaf, pre_leaf):
            P, page = pool_leaf.shape[-4], pool_leaf.shape[-3]
            nkv, hd = pool_leaf.shape[-2], pool_leaf.shape[-1]
            lead = pool_leaf.shape[:-4]
            src = jnp.take(pre_leaf, row, axis=len(lead))  # (*lead, W, NKV, HD)
            flat = pool_leaf.reshape(*lead, P * page, nkv, hd)
            if lead:
                flat = flat.at[:, flat_idx].set(src)
            else:
                flat = flat.at[flat_idx].set(src)
            return flat.reshape(*lead, P, page, nkv, hd)

        return one(pool, pre)

    new_periods = tuple(
        {
            "kp": graft_leaves(pc["kp"], pf["k"]),
            "vp": graft_leaves(pc["vp"], pf["v"]),
        }
        for pc, pf in zip(paged_cache["periods"], prefill_cache["periods"])
    )
    new_epilogue = tuple(
        {
            "kp": graft_leaves(pc["kp"], pf["k"]),
            "vp": graft_leaves(pc["vp"], pf["v"]),
        }
        for pc, pf in zip(paged_cache["epilogue"], prefill_cache["epilogue"])
    )
    return {"periods": new_periods, "epilogue": new_epilogue}


def graft_prefill_batch(cfg, paged_cache, prefill_cache, page_tables,
                        page_size: int):
    """Copy *every* prefilled row's KV state into its slot's pages at once.

    Batched companion to :func:`graft_prefill`: ``page_tables`` is
    ``(B, NB)`` int32 — one table per prefill row — and all ``B * W``
    positions scatter in a single operation, so joining a chunk costs one
    dispatch instead of one per row.  Padded ladder rows carry an all-trash
    table: their writes collapse into the reserved trash page (overlapping
    writes there are harmless — nothing masked-in ever reads it).
    """
    idx = jnp.arange(prefill_cache_width(prefill_cache))
    flat_idx = (
        page_tables[:, idx // page_size] * page_size + idx % page_size
    ).reshape(-1)  # (B*W,) flat pool positions

    def graft_leaves(pool_leaf, pre_leaf):
        # pool: (*lead, P, page, NKV, HD); pre: (*lead, B, W, NKV, HD)
        P, page = pool_leaf.shape[-4], pool_leaf.shape[-3]
        nkv, hd = pool_leaf.shape[-2], pool_leaf.shape[-1]
        lead = pool_leaf.shape[:-4]
        src = pre_leaf.reshape(*lead, -1, nkv, hd)  # (*lead, B*W, NKV, HD)
        flat = pool_leaf.reshape(*lead, P * page, nkv, hd)
        if lead:
            flat = flat.at[:, flat_idx].set(src)
        else:
            flat = flat.at[flat_idx].set(src)
        return flat.reshape(*lead, P, page, nkv, hd)

    new_periods = tuple(
        {
            "kp": graft_leaves(pc["kp"], pf["k"]),
            "vp": graft_leaves(pc["vp"], pf["v"]),
        }
        for pc, pf in zip(paged_cache["periods"], prefill_cache["periods"])
    )
    new_epilogue = tuple(
        {
            "kp": graft_leaves(pc["kp"], pf["k"]),
            "vp": graft_leaves(pc["vp"], pf["v"]),
        }
        for pc, pf in zip(paged_cache["epilogue"], prefill_cache["epilogue"])
    )
    return {"periods": new_periods, "epilogue": new_epilogue}


def prefill_cache_width(prefill_cache) -> int:
    """Sequence width of a dense prefill cache (its ring length)."""
    for group in (prefill_cache["periods"], prefill_cache["epilogue"]):
        for layer in group:
            if "k" in layer:
                return layer["k"].shape[-3]
    raise ValueError("prefill cache has no attention layers")


def paged_decode_step(cfg, params, paged_cache, page_tables, token, pos,
                      page_size: int):
    """One decode step over the shared page pool.

    token/pos: (B,) int32 — per-row positions (rows need *not* be in
    lockstep; that is the point).  ``page_tables``: (B, NB) int32.
    Inactive rows should carry pos=0 and an all-trash table.
    """
    inputs = {"tokens": token[:, None]}
    x, new_cache, _ = forward_hidden(
        cfg, params, inputs, cache=paged_cache, decode=True,
        positions=pos[:, None], page_tables=page_tables, page_size=page_size,
    )
    logits = _unembed(cfg, params, x)[:, 0]
    return logits, new_cache
