"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

TPU adaptation notes (recorded in DESIGN.md):
  * mLSTM is evaluated in its *chunkwise-parallel* form — quadratic within a
    chunk (MXU-friendly matmuls with a decay mask), sequential state carry
    across chunks — the standard linear-attention-with-decay factorization.
    Decode is the O(1) recurrent update (this is what makes ``long_500k``
    tractable).
  * We use sigmoid input/forget gates (log-gates <= 0) instead of the paper's
    exponential input gate, trading a little expressivity for an
    unconditionally stable decay matrix (no running-max stabilizer needed in
    the chunkwise form).  The sequential sLSTM keeps the exponential-gate
    formulation with the standard m_t running-max stabilizer.
  * sLSTM is inherently sequential (recurrent connections through h_{t-1});
    it runs as a ``lax.scan`` over time.  Its FLOPs are tiny relative to the
    mLSTM blocks (1:8 ratio in the 350m config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "mlstm_init_spec",
    "mlstm_apply",
    "mlstm_decode_step",
    "mlstm_init_cache",
    "slstm_init_spec",
    "slstm_apply",
    "slstm_decode_step",
    "slstm_init_cache",
]


# ---------------------------------------------------------------------------
# mLSTM.
# ---------------------------------------------------------------------------
def _dims(cfg):
    di = int(cfg.d_model * cfg.xlstm_proj_factor)
    nh = cfg.xlstm_heads
    return di, nh, di // nh


def mlstm_init_spec(cfg):
    d = cfg.d_model
    di, nh, _ = _dims(cfg)
    return {
        "wq": ((d, di), ("embed", "lru")),
        "wk": ((d, di), ("embed", "lru")),
        "wv": ((d, di), ("embed", "lru")),
        "wz": ((d, di), ("embed", "lru")),  # output-gate branch
        "wi": ((d, nh), ("embed", None)),  # input gate (per head)
        "wf": ((d, nh), ("embed", None)),  # forget gate (per head)
        "bi": ((nh,), (None,)),
        "bf": ((nh,), (None,)),
        "wo": ((di, d), ("lru", "embed")),
    }


def _mlstm_qkvg(cfg, params, x):
    B, S, _ = x.shape
    di, nh, dh = _dims(cfg)
    q = (x @ params["wq"]).reshape(B, S, nh, dh)
    k = (x @ params["wk"]).reshape(B, S, nh, dh) * (dh**-0.5)
    v = (x @ params["wv"]).reshape(B, S, nh, dh)
    z = jax.nn.silu(x @ params["wz"])
    xf = x.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(xf @ params["wf"].astype(jnp.float32) + params["bf"])
    gate_i = jax.nn.sigmoid(xf @ params["wi"].astype(jnp.float32) + params["bi"])
    return q, k, v, z, log_f, gate_i  # gates: (B, S, NH) fp32


def _mlstm_chunk(q, k, v, log_f, gate_i, carry):
    """One chunk.  q,k,v: (B, L, NH, dh); gates (B, L, NH); carry (C, n)."""
    C_prev, n_prev = carry  # (B, NH, dh, dh), (B, NH, dh)
    lf = jnp.cumsum(log_f, axis=1)  # inclusive cumulative log-decay
    # Intra-chunk decay matrix D_ij = exp(lf_i - lf_j) * i_j  for j <= i.
    diff = lf[:, :, None, :] - lf[:, None, :, :]  # (B, L, L, NH)
    mask = jnp.tril(jnp.ones((q.shape[1], q.shape[1]), bool))
    D = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0) * gate_i[:, None, :, :]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    scores = jnp.einsum("blhd,bmhd->blmh", qf, kf) * D  # (B, L, L, NH)
    h_intra = jnp.einsum("blmh,bmhd->blhd", scores, vf)
    n_intra = jnp.einsum("blmh,bmhd->blhd", D, kf)

    decay_q = jnp.exp(lf)  # (B, L, NH)
    h_inter = jnp.einsum("blhd,bhde->blhe", qf * decay_q[..., None], C_prev)
    n_inter = decay_q[..., None] * n_prev[:, None]  # (B, L, NH, dh)

    h = h_intra + h_inter
    n = n_intra + n_inter
    denom = jnp.maximum(jnp.abs(jnp.einsum("blhd,blhd->blh", qf, n)), 1.0)
    out = h / denom[..., None]

    # State update to the end of the chunk.
    decay_to_end = jnp.exp(lf[:, -1:, :] - lf)  # (B, L, NH)
    kv = jnp.einsum(
        "blhd,blhe->bhde", kf * (decay_to_end * gate_i)[..., None], vf
    )
    C_new = jnp.exp(lf[:, -1])[:, :, None, None] * C_prev + kv
    k_sum = jnp.einsum("blh,blhd->bhd", decay_to_end * gate_i, kf)
    n_new = jnp.exp(lf[:, -1])[:, :, None] * n_prev + k_sum
    return out, (C_new, n_new)


def mlstm_apply(cfg, params, x, carry=None):
    """Chunkwise-parallel mLSTM.  x: (B, S, D) -> (B, S, D)."""
    B, S, d = x.shape
    di, nh, dh = _dims(cfg)
    L = min(cfg.xlstm_chunk, S)
    if S % L:
        raise ValueError(f"seq {S} not divisible by xlstm_chunk {L}")
    q, k, v, z, log_f, gate_i = _mlstm_qkvg(cfg, params, x)
    if carry is None:
        carry = (
            jnp.zeros((B, nh, dh, dh), jnp.float32),
            jnp.zeros((B, nh, dh), jnp.float32),
        )

    nc = S // L
    resh = lambda t: t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)
    qs, ks, vs, lfs, gis = map(resh, (q, k, v, log_f, gate_i))

    def body(c, inp):
        qq, kk, vv, lf, gi = inp
        out, c2 = _mlstm_chunk(qq, kk, vv, lf, gi, c)
        return c2, out

    carry, outs = jax.lax.scan(
        body, carry, (qs, ks, vs, lfs, gis), unroll=cfg.unroll_scans
    )
    h = outs.swapaxes(0, 1).reshape(B, S, nh, dh).reshape(B, S, di)
    out = (h.astype(x.dtype) * z) @ params["wo"]
    return out, carry


def mlstm_init_cache(cfg, batch):
    _, nh, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
    }


def mlstm_decode_step(cfg, params, x, cache):
    """One token, O(1) state.  x: (B, 1, D)."""
    B = x.shape[0]
    di, nh, dh = _dims(cfg)
    q, k, v, z, log_f, gate_i = _mlstm_qkvg(cfg, params, x)
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (B, NH, dh)
    f = jnp.exp(log_f[:, 0])[..., None]  # (B, NH, 1)
    i = gate_i[:, 0][..., None]
    C = f[..., None] * cache["C"] + i[..., None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = f * cache["n"] + i * kf
    h = jnp.einsum("bhd,bhde->bhe", qf, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    h = (h / denom[..., None]).reshape(B, 1, di)
    out = (h.astype(x.dtype) * z) @ params["wo"]
    return out, {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM.
# ---------------------------------------------------------------------------
def slstm_init_spec(cfg):
    d = cfg.d_model
    nh = cfg.xlstm_heads
    dh = d // nh
    spec = {}
    for g in ("i", "f", "z", "o"):
        spec[f"w{g}"] = ((d, d), ("embed", "lru"))
        spec[f"r{g}"] = ((nh, dh, dh), (None, "lru", None))  # block-diag recurrence
        spec[f"b{g}"] = ((d,), ("lru",))
    spec["wo_proj"] = ((d, d), ("lru", "embed"))
    return spec


def _slstm_step(params, nh, x_t, state):
    """x_t: (B, D) fp32. state: dict(c, n, h, m) each (B, D)-ish fp32."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    B, d = x_t.shape
    dh = d // nh
    hh = h.reshape(B, nh, dh)

    def gate(name):
        rec = jnp.einsum("bhd,hde->bhe", hh, params[f"r{name}"]).reshape(B, d)
        return x_t @ params[f"w{name}"] + rec + params[f"b{name}"]

    it, ft = gate("i"), gate("f")
    zt = jnp.tanh(gate("z"))
    ot = jax.nn.sigmoid(gate("o"))
    # Stabilized exponential gating (xLSTM eq. 15-17).
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_init_cache(cfg, batch):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": z()}


def slstm_apply(cfg, params, x, state=None):
    """Sequential sLSTM over the sequence.  x: (B, S, D)."""
    B, S, d = x.shape
    nh = cfg.xlstm_heads
    if state is None:
        state = slstm_init_cache(cfg, B)
    xf = x.astype(jnp.float32)

    def body(st, x_t):
        st2 = _slstm_step(params, nh, x_t, st)
        return st2, st2["h"]

    state, hs = jax.lax.scan(body, state, xf.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).astype(x.dtype) @ params["wo_proj"]
    return out, state


def slstm_decode_step(cfg, params, x, state):
    st = _slstm_step(params, cfg.xlstm_heads, x[:, 0].astype(jnp.float32), state)
    out = st["h"][:, None].astype(x.dtype) @ params["wo_proj"]
    return out, st
