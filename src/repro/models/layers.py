"""Shared layers: RMSNorm, rotary embeddings, dense MLPs, embedding tables."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.api import constrain

__all__ = [
    "rms_norm",
    "rope",
    "apply_rope",
    "mlp_init_spec",
    "mlp_apply",
    "dense_init",
    "truncated_normal_init",
]


def truncated_normal_init(key, shape, dtype, scale: float):
    """He-style truncated normal, stddev = scale / sqrt(fan_in)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def dense_init(key, shape, dtype):
    return truncated_normal_init(key, shape, dtype, 1.0)


def rms_norm(x, weight, *, eps: float = 1e-6, offset: bool = False):
    """RMSNorm; ``offset=True`` uses the gemma (1 + w) parameterization."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    scale = (1.0 + weight.astype(jnp.float32)) if offset else weight.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def rope(positions, head_dim: int, theta: float):
    """Rotary position embedding tables.

    Args:
      positions: (..., S) int32 absolute positions.
      head_dim: must be even.
    Returns:
      (sin, cos) each (..., S, head_dim // 2) float32.
    """
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    return jnp.sin(angle), jnp.cos(angle)


def apply_rope(x, sin, cos):
    """Rotate pairs. x: (B, S, N, HD); sin/cos: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == x.ndim - 1:  # (B, S, half) -> broadcast over heads
        sin = sin[..., None, :]
        cos = cos[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU / GELU).  Spec tables keep init + logical axes in
# one place so parameter trees and sharding specs cannot drift.
# ---------------------------------------------------------------------------
def mlp_init_spec(d_model: int, d_ff: int, mlp_type: str):
    """Returns {name: (shape, logical_axes)} for one MLP."""
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wi": ((d_model, d_ff), ("embed", "ffn")),
            "wg": ((d_model, d_ff), ("embed", "ffn")),
            "wo": ((d_ff, d_model), ("ffn", "embed")),
        }
    if mlp_type == "gelu":
        return {
            "wi": ((d_model, d_ff), ("embed", "ffn")),
            "wo": ((d_ff, d_model), ("ffn", "embed")),
        }
    raise ValueError(f"unknown mlp_type {mlp_type!r}")


def mlp_apply(params, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["wi"]) * (x @ params["wg"])
    elif mlp_type == "geglu":
        h = jax.nn.gelu(x @ params["wi"], approximate=True) * (x @ params["wg"])
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["wi"], approximate=True)
    else:
        raise ValueError(mlp_type)
    h = constrain(h, "batch", "seq", "ffn")
    return h @ params["wo"]
