"""Architecture configuration for the LM zoo.

One :class:`ModelConfig` describes any of the 10 assigned architectures;
``pattern`` expresses heterogeneous block stacks (RecurrentGemma's 2:1
recurrent:attention pattern, xLSTM's mLSTM/sLSTM mix) as one *period* that
repeats ``n_layers // len(pattern)`` times (plus an explicit epilogue for
non-divisible depths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "BLOCK_KINDS"]

# Block kinds usable in ``pattern``:
#   "attn"      global attention + dense MLP
#   "local"     sliding-window attention + dense MLP
#   "moe"       global attention + mixture-of-experts MLP
#   "recurrent" conv1d + RG-LRU gated linear recurrence + dense MLP
#   "mlstm"     xLSTM matrix-memory block (self-contained, no separate MLP)
#   "slstm"     xLSTM scalar-memory block (sequential recurrence)
BLOCK_KINDS = ("attn", "local", "moe", "recurrent", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    pattern: Tuple[str, ...] = ("attn",)
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    window: int = 0  # sliding-window size for "local" blocks
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True  # False => bidirectional encoder (HuBERT)
    prefix_lm: bool = False  # PaliGemma: bidirectional over the image prefix

    # Mixture of experts ("moe" blocks).
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0  # Llama-4 shared expert
    capacity_factor: float = 1.25
    router_type: str = "softmax"  # softmax | sigmoid (llama4 top-1)

    # Recurrent ("recurrent" = RG-LRU) blocks.
    lru_width: int = 0
    conv_width: int = 4

    # xLSTM blocks.
    xlstm_proj_factor: float = 2.0
    xlstm_heads: int = 4
    xlstm_chunk: int = 64

    # Frontend stubs ([audio]/[vlm] backbones take precomputed embeddings).
    frontend: str = "none"  # none | audio | vision
    frontend_dim: int = 0  # raw embedding dim fed by the stub
    num_prefix_tokens: int = 0  # vision patches prepended to the text

    tie_embeddings: bool = True
    emb_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    norm_offset: bool = False  # gemma: RMSNorm scale is (1 + w)
    norm_eps: float = 1e-6

    # Execution knobs (not architecture).
    dtype: str = "bfloat16"
    attn_chunk: int = 1024  # flash-attention chunk length
    scan_layers: bool = True
    remat: bool = True  # checkpoint each period during training
    use_pallas: bool = False  # TPU kernels; pure-JAX path otherwise
    unroll_scans: bool = False  # unroll inner scans (cost-analysis compiles)
    moe_groups: int = 1  # token groups for MoE dispatch (launcher overrides)
    kv_cache_quant: bool = False  # int8 KV cache (per-entry scales)
    loss_chunk: int = 512  # sequence chunking of the softmax-xent loss

    # ---------------------------------------------------------------
    def __post_init__(self):
        for k in self.pattern:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if "moe" in self.pattern and not (self.n_experts and self.top_k):
            raise ValueError("moe blocks need n_experts and top_k")

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def epilogue(self) -> Tuple[str, ...]:
        """Layer kinds beyond the last full period (e.g. RecurrentGemma 26L)."""
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state is O(1)/O(window) — long_500k eligible."""
        return all(k in ("recurrent", "mlstm", "slstm", "local") for k in self.pattern)

    def layer_kinds(self) -> Tuple[str, ...]:
        return self.pattern * self.n_periods + self.epilogue

    # Rough parameter count (for roofline MODEL_FLOPS = 6*N*D).
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = 0
        for kind in self.layer_kinds():
            if kind in ("attn", "local", "moe"):
                attn = d * hd * (nq + 2 * nkv) + nq * hd * d
                if kind == "moe":
                    n_e = self.top_k if active_only else self.n_experts
                    gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                    mlp = n_e * gates * d * self.expert_d_ff
                    mlp += self.n_shared_experts * gates * d * self.expert_d_ff
                    mlp += d * self.n_experts  # router
                else:
                    gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                    mlp = gates * d * self.d_ff
                total += attn + mlp
            elif kind == "recurrent":
                w = self.lru_width
                total += 2 * d * w + w * d + self.conv_width * w + 2 * w
                gates = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                total += gates * d * self.d_ff
            elif kind == "mlstm":
                di = int(self.d_model * self.xlstm_proj_factor)
                total += d * di * 5 + 2 * di * self.xlstm_heads + di * d
            elif kind == "slstm":
                di = d
                total += 4 * (d * di + di * di // self.xlstm_heads) + di * d
        total += self.vocab_size * d  # embeddings (tied head)
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return total
