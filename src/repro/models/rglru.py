"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence is
    r_t = sigmoid(W_a x_t + b_a)                    (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                    (input gate)
    log a_t = -c * softplus(Lambda) * r_t           (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence —
O(log S) depth, fully parallel (the TPU-native replacement for the paper's
sequential CUDA scan); decode carries h (O(1) state, which is what makes the
``long_500k`` cell tractable for this family).

Block layout (Griffin "recurrent block"): a gated-linear-unit style pair of
input projections; the recurrent branch passes through a short depthwise
conv1d (width 4) and the RG-LRU; branches merge multiplicatively and project
back to d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rglru_init_spec",
    "rglru_apply",
    "rglru_decode_step",
    "rglru_init_cache",
    "C_CONST",
]

C_CONST = 8.0


def rglru_init_spec(cfg):
    d, w = cfg.d_model, cfg.lru_width
    return {
        "wx": ((d, w), ("embed", "lru")),  # recurrent-branch input proj
        "wy": ((d, w), ("embed", "lru")),  # gate branch
        "wo": ((w, d), ("lru", "embed")),
        "conv_w": ((cfg.conv_width, w), (None, "lru")),
        "conv_b": ((w,), ("lru",)),
        "gate_a": ((w, w), ("lru", None)),  # W_a (recurrence gate)
        "gate_x": ((w, w), ("lru", None)),  # W_x (input gate)
        "gate_a_b": ((w,), ("lru",)),
        "gate_x_b": ((w,), ("lru",)),
        "lamb": ((w,), ("lru",)),  # Lambda (learned decay)
    }


def _depthwise_conv(x, conv_w, conv_b, tail=None):
    """Causal depthwise conv1d.  x: (B, S, W); conv_w: (K, W)."""
    k = conv_w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail  # (B, K-1, W) from the previous step (decode)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * conv_w[i][None, None, :] for i in range(k)
    )
    new_tail = xp[:, -(k - 1) :] if k > 1 else jnp.zeros_like(pad)
    return out + conv_b, new_tail


def _gates(params, x):
    """log_a (decay) and gated input for the RG-LRU.  x: (..., W)."""
    r = jax.nn.sigmoid(x @ params["gate_a"] + params["gate_a_b"])
    i = jax.nn.sigmoid(x @ params["gate_x"] + params["gate_x_b"])
    log_a = -C_CONST * jax.nn.softplus(params["lamb"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) normalizer keeps the state norm bounded.
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * (i * x)


def _lru_scan(a, bx, h0=None):
    """h_t = a_t h_{t-1} + bx_t via associative scan.  a, bx: (B, S, W)."""
    if h0 is not None:
        # Fold the carried state into the first element.
        bx = bx.at[:, 0].add(a[:, 0] * h0)
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_apply(cfg, params, x, h0=None, conv_tail=None):
    """Full-sequence recurrent block.  x: (B, S, D) -> (B, S, D).

    Returns (out, (h_last, conv_tail)) so prefill can seed decode.
    """
    dtype = x.dtype
    y = jax.nn.gelu((x @ params["wy"]).astype(jnp.float32), approximate=True)
    u = x @ params["wx"]
    u, new_tail = _depthwise_conv(u, params["conv_w"], params["conv_b"], conv_tail)
    a, bx = _gates(params, u.astype(jnp.float32))
    h = _lru_scan(a, bx, h0)
    out = (h * y).astype(dtype) @ params["wo"]
    return out, (h[:, -1], new_tail)


def rglru_init_cache(cfg, batch, dtype=jnp.float32):
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv_tail": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode_step(cfg, params, x, cache):
    """One token.  x: (B, 1, D) -> (B, 1, D); O(1) state update."""
    dtype = x.dtype
    y = jax.nn.gelu((x @ params["wy"]).astype(jnp.float32), approximate=True)
    u = x @ params["wx"]
    u, new_tail = _depthwise_conv(
        u, params["conv_w"], params["conv_b"], cache["conv_tail"]
    )
    a, bx = _gates(params, u.astype(jnp.float32))
    h = a[:, 0] * cache["h"] + bx[:, 0]  # (B, W)
    out = (h[:, None] * y).astype(dtype) @ params["wo"]
    return out, {"h": h, "conv_tail": new_tail}
