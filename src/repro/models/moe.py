"""Mixture-of-experts FFN with sort-based (gather/scatter) dispatch.

Design notes (TPU adaptation):
  * The classic GShard dispatch einsum builds a (T, E, C) one-hot and costs
    ``2*T*D*E*C`` FLOPs — with E*C ~= k*cf*T that is *quadratic in tokens*
    and can exceed the expert FFN FLOPs themselves.  We instead sort token
    assignments by expert and move tokens with gathers/scatters (O(T*k*D)
    bytes, ~0 FLOPs), the same idea behind MegaBlocks/ragged dispatch, but
    expressed with XLA sort+scatter so it runs everywhere.
  * Sharding: tokens are regrouped into ``cfg.moe_groups`` groups, each group
    local to a device slice (logical axis "moe_groups" -> all mesh axes).
    Expert weights are sharded FSDP on d_model ("embed") and tensor-parallel
    on the per-expert hidden ("expert_ffn") — so expert compute needs no
    token all-to-all; GSPMD inserts the weight all-gather (FSDP) and the
    output reduce (TP).  An EP/all-to-all layout is evaluated against this
    in EXPERIMENTS.md §Perf.
  * Capacity: per-group, ``C = ceil(T_group * top_k * capacity_factor / E)``;
    overflow tokens are dropped (their combine weight is zero) — standard
    dropped-token semantics, exercised by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.api import constrain
from repro.models import layers

__all__ = ["moe_init_spec", "moe_apply", "capacity"]


def capacity(tokens_per_group: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(np.ceil(tokens_per_group * top_k * cf / n_experts))
    return max(c, top_k)


def moe_init_spec(cfg):
    """{name: (shape, logical_axes)} for one MoE block's FFN."""
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    spec = {
        "router": ((d, e), ("embed", "experts")),
        "wi": ((e, d, f), ("experts", "embed", "expert_ffn")),
        "wg": ((e, d, f), ("experts", "embed", "expert_ffn")),
        "wo": ((e, f, d), ("experts", "expert_ffn", "embed")),
    }
    if cfg.mlp_type == "gelu":
        del spec["wg"]
    if cfg.n_shared_experts:
        sf = cfg.expert_d_ff * cfg.n_shared_experts
        spec.update(
            {
                "shared_wi": ((d, sf), ("embed", "ffn")),
                "shared_wg": ((d, sf), ("embed", "ffn")),
                "shared_wo": ((sf, d), ("ffn", "embed")),
            }
        )
    return spec


def _route(cfg, router_w, x):
    """Router: top-k expert ids + gate values per token.  x: (T, D)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    if cfg.router_type == "sigmoid":
        # Llama-4 style: pick top-k by logit, gate with sigmoid.
        gates_all = jax.nn.sigmoid(logits)
        top_logits, top_idx = jax.lax.top_k(logits, cfg.top_k)
        top_gate = jnp.take_along_axis(gates_all, top_idx, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_gate, top_idx = jax.lax.top_k(probs, cfg.top_k)
        top_gate = top_gate / jnp.maximum(top_gate.sum(-1, keepdims=True), 1e-9)
    # Aux load-balancing loss (Switch): E * sum_e f_e * p_e.
    e = cfg.n_experts
    me = jax.nn.one_hot(top_idx[..., 0], e).mean(0)
    pe = jax.nn.softmax(logits, axis=-1).mean(0)
    aux = e * jnp.sum(me * pe)
    return top_idx, top_gate, aux


def _dispatch_group(cfg, params, x, cap):
    """One group: x (T, D) -> (T, D).  Sort-based dispatch."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    top_idx, top_gate, aux = _route(cfg, params["router"], x)

    tk = T * k
    flat_e = top_idx.reshape(tk)  # expert id per (token, slot)
    flat_g = top_gate.reshape(tk)
    flat_t = jnp.arange(tk, dtype=jnp.int32) // k  # source token per slot

    order = jnp.argsort(flat_e, stable=True)  # group identical experts
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # Position within each expert's run of the sorted array.
    run_start = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(tk, dtype=jnp.int32) - run_start
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    # Gather tokens into the (E, C, D) expert buffer (dropped -> zeros).
    xt = jnp.where(keep[:, None], x[st], 0.0)
    buf = jnp.zeros((E, cap, D), x.dtype).at[se, pos_c].add(
        xt, mode="drop"
    )

    # Expert FFN (dense over the buffer).
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True)
        )
        h = act(h) * g
    else:
        h = jax.nn.gelu(h, approximate=True)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"])

    # Combine: gather expert outputs back to token order, weighted.
    back = out_buf[se, pos_c] * (sg * keep)[:, None].astype(out_buf.dtype)
    out = jnp.zeros((T, D), out_buf.dtype).at[st].add(back, mode="drop")
    return out, aux


def moe_apply(cfg, params, x):
    """x: (B, S, D) -> (B, S, D) plus aux loss scalar."""
    B, S, D = x.shape
    g = cfg.moe_groups
    total = B * S
    if total % g:
        raise ValueError(f"tokens {total} not divisible by moe_groups {g}")
    tpg = total // g
    cap = capacity(tpg, cfg.n_experts, cfg.top_k, cfg.capacity_factor)

    xg = x.reshape(g, tpg, D)
    xg = constrain(xg, "moe_groups", None, None)
    out, aux = jax.vmap(lambda xi: _dispatch_group(cfg, params, xi, cap))(xg)
    out = constrain(out, "moe_groups", None, None)
    out = out.reshape(B, S, D).astype(x.dtype)

    if cfg.n_shared_experts:
        shared = layers.mlp_apply(
            {"wi": params["shared_wi"], "wg": params["shared_wg"], "wo": params["shared_wo"]},
            x,
            "swiglu" if cfg.mlp_type != "gelu" else "gelu",
        )
        out = out + shared
    return out, aux.mean()
