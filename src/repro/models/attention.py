"""Chunked online-softmax ("flash") attention in pure JAX, with a custom VJP.

Why a custom VJP: the naive differentiation of an online-softmax scan saves
every per-step carry (the running (B,C,KV,G,HD) accumulator), which is
quadratic memory — we measured a 48-layer llama step ballooning to 157 GB of
temps.  The flash backward recomputes block probabilities from the saved
log-sum-exp instead, keeping attention memory O(S).

Layout: q is grouped as (B, S, KV, G, HD) so GQA never materializes repeated
K/V.  The outer loop over query chunks is a static Python loop (exact causal
FLOPs — no masked-out off-diagonal blocks are ever computed); the inner loop
over key chunks is a ``lax.scan``.

Masks: causal, sliding window (RecurrentGemma local attention), bidirectional
(HuBERT), and prefix-LM (PaliGemma — requires prefix length <= chunk so the
non-causal pairs stay inside the diagonal block; asserted).

This is also the reference algorithm for the Pallas TPU kernel in
``repro.kernels.flash_attention`` (same tiling, VMEM-resident accumulators).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain

__all__ = [
    "flash_attention",
    "attention_reference",
    "decode_attention",
    "paged_decode_attention",
]

_NEG_INF = -1e30


def _block_mask(qpos, kpos, *, causal: bool, window: int, prefix_len):
    """(B?, C, C2) boolean mask of allowed attention pairs."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    if prefix_len is not None:
        # Bidirectional visibility of/within the prefix region.
        ok = ok[None] | (kpos[None, None, :] < prefix_len[:, None, None])
    return ok  # (C, C2) or (B, C, C2)


def _expand_mask(ok):
    """-> broadcastable against scores (B, KV, G, C, C2)."""
    if ok.ndim == 2:
        return ok[None, None, None]
    return ok[:, None, None]  # batch-dependent (prefix-LM)


def _kv_chunk_range(qi: int, n_kv: int, chunk: int, *, causal: bool, window: int):
    """Static [start, end) of key chunks needed by query chunk ``qi``."""
    if not causal:
        return 0, n_kv
    end = qi + 1
    start = 0
    if window:
        start = max(0, (qi * chunk - window + 1) // chunk)
    return start, end


def _pick_chunk(s: int, chunk: int) -> int:
    if s <= chunk:
        return s
    if s % chunk == 0:
        return chunk
    # Largest divisor of s that is <= chunk (keeps odd lengths working).
    for c in range(chunk, 0, -1):
        if s % c == 0:
            return c
    return s


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------
def _flash_fwd_impl(q, k, v, prefix_len, causal, window, chunk, scale, unroll=False):
    B, Sq, KV, G, HD = q.shape
    Skv = k.shape[1]
    C = _pick_chunk(Sq, chunk)
    C2 = _pick_chunk(Skv, chunk)
    nq, nkv = Sq // C, Skv // C2

    outs, lses = [], []
    for qi in range(nq):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * C, C, axis=1)
        qpos_c = qi * C + jnp.arange(C)
        start, end = _kv_chunk_range(qi, nkv, C2, causal=causal, window=window)

        acc0 = jnp.zeros((B, C, KV, G, HD), jnp.float32)
        m0 = jnp.full((B, KV, G, C), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, C), jnp.float32)

        def body(carry, kj, qc=qc, qpos_c=qpos_c, C2=C2):
            acc, m, l = carry
            ks = jax.lax.dynamic_slice_in_dim(k, kj * C2, C2, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kj * C2, C2, axis=1)
            kpos = kj * C2 + jnp.arange(C2)
            s = (
                jnp.einsum("bqkgd,bskd->bkgqs", qc, ks, preferred_element_type=jnp.float32)
                * scale
            )
            ok = _expand_mask(
                _block_mask(qpos_c, kpos, causal=causal, window=window, prefix_len=prefix_len)
            )
            s = jnp.where(ok, s, _NEG_INF)
            mn = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - mn[..., None])
            corr = jnp.exp(m - mn)
            l2 = l * corr + p.sum(-1)
            pv = jnp.einsum(
                "bkgqs,bskd->bqkgd", p.astype(v.dtype), vs, preferred_element_type=jnp.float32
            )
            acc2 = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
            return (acc2, mn, l2), None

        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0), jnp.arange(start, end), unroll=unroll
        )
        out_c = acc / jnp.maximum(jnp.moveaxis(l, -1, 1), 1e-30)[..., None]
        outs.append(out_c.astype(q.dtype))
        lses.append(m + jnp.log(jnp.maximum(l, 1e-30)))  # (B, KV, G, C)

    out = jnp.concatenate(outs, axis=1)
    lse = jnp.concatenate(lses, axis=-1)  # (B, KV, G, Sq)
    return out, lse


# ---------------------------------------------------------------------------
# Backward (recompute-from-LSE, standard flash backward).
# ---------------------------------------------------------------------------
def _flash_bwd_impl(q, k, v, prefix_len, out, lse, dout, causal, window, chunk,
                    scale, unroll=False):
    B, Sq, KV, G, HD = q.shape
    Skv = k.shape[1]
    C = _pick_chunk(Sq, chunk)
    C2 = _pick_chunk(Skv, chunk)
    nq, nkv = Sq // C, Skv // C2

    # delta_i = sum_d dout_i * out_i  (per query position).
    delta = jnp.einsum(
        "bqkgd,bqkgd->bkgq", dout.astype(jnp.float32), out.astype(jnp.float32)
    )  # (B, KV, G, Sq)

    dk = jnp.zeros((B, Skv, KV, HD), jnp.float32)
    dv = jnp.zeros((B, Skv, KV, HD), jnp.float32)
    dqs = []
    for qi in range(nq):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * C, C, axis=1)
        doc = jax.lax.dynamic_slice_in_dim(dout, qi * C, C, axis=1).astype(jnp.float32)
        lse_c = jax.lax.dynamic_slice_in_dim(lse, qi * C, C, axis=-1)
        delta_c = jax.lax.dynamic_slice_in_dim(delta, qi * C, C, axis=-1)
        qpos_c = qi * C + jnp.arange(C)
        start, end = _kv_chunk_range(qi, nkv, C2, causal=causal, window=window)

        dq0 = jnp.zeros((B, C, KV, G, HD), jnp.float32)

        def body(carry, kj, qc=qc, doc=doc, lse_c=lse_c, delta_c=delta_c, qpos_c=qpos_c, C2=C2):
            dq_c, dk_acc, dv_acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, kj * C2, C2, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, kj * C2, C2, axis=1)
            kpos = kj * C2 + jnp.arange(C2)
            s = (
                jnp.einsum("bqkgd,bskd->bkgqs", qc, ks, preferred_element_type=jnp.float32)
                * scale
            )
            ok = _expand_mask(
                _block_mask(qpos_c, kpos, causal=causal, window=window, prefix_len=prefix_len)
            )
            p = jnp.where(ok, jnp.exp(s - lse_c[..., None]), 0.0)  # (B,KV,G,C,C2)
            dv_c = jnp.einsum("bkgqs,bqkgd->bskd", p, doc)
            dp = jnp.einsum(
                "bqkgd,bskd->bkgqs", doc, vs.astype(jnp.float32)
            )
            ds = p * (dp - delta_c[..., None]) * scale
            dq_c = dq_c + jnp.einsum("bkgqs,bskd->bqkgd", ds, ks.astype(jnp.float32))
            dk_c = jnp.einsum("bkgqs,bqkgd->bskd", ds, qc.astype(jnp.float32))
            off = kj * C2
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, off, C2, 1) + dk_c, off, 1
            )
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, off, C2, 1) + dv_c, off, 1
            )
            return (dq_c, dk_acc, dv_acc), None

        (dq_c, dk, dv), _ = jax.lax.scan(
            body, (dq0, dk, dv), jnp.arange(start, end), unroll=unroll
        )
        dqs.append(dq_c.astype(q.dtype))

    dq = jnp.concatenate(dqs, axis=1)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# custom_vjp plumbing.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(q, k, v, prefix_len, causal, window, chunk, scale, unroll):
    out, _ = _flash_fwd_impl(q, k, v, prefix_len, causal, window, chunk, scale,
                             unroll=unroll)
    return out


def _flash_fwd(q, k, v, prefix_len, causal, window, chunk, scale, unroll):
    out, lse = _flash_fwd_impl(q, k, v, prefix_len, causal, window, chunk, scale,
                               unroll=unroll)
    return out, (q, k, v, prefix_len, out, lse)


def _flash_bwd(causal, window, chunk, scale, unroll, res, dout):
    q, k, v, prefix_len, out, lse = res
    dq, dk, dv = _flash_bwd_impl(
        q, k, v, prefix_len, out, lse, dout, causal, window, chunk, scale,
        unroll=unroll,
    )
    return dq, dk, dv, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    prefix_len: Optional[jax.Array] = None,
    chunk: int = 1024,
    scale: Optional[float] = None,
    unroll: bool = False,
):
    """Memory-efficient attention.

    Args:
      q: (B, Sq, NQ, HD); k, v: (B, Skv, NKV, HD) with NQ % NKV == 0.
      causal: causal masking (False => full bidirectional, encoder-style).
      window: sliding window size (0 = unlimited). Implies causal bounds.
      prefix_len: (B,) optional prefix-LM boundary; requires
        ``max(prefix_len) <= chunk`` (PaliGemma: 256 <= 1024).
      chunk: query/key chunk length (VMEM tile on TPU).
    Returns:
      (B, Sq, NQ, HD) in q.dtype.
    """
    B, Sq, NQ, HD = q.shape
    NKV = k.shape[2]
    G = NQ // NKV
    if scale is None:
        scale = HD**-0.5
    qg = q.reshape(B, Sq, NKV, G, HD)
    out = _flash(qg, k, v, prefix_len, causal, window, chunk, scale, unroll)
    return out.reshape(B, Sq, NQ, HD)


# ---------------------------------------------------------------------------
# Reference (naive, O(S^2) memory) — oracle for tests and tiny models.
# ---------------------------------------------------------------------------
def attention_reference(
    q, k, v, *, causal=True, window=0, prefix_len=None, scale=None
):
    B, Sq, NQ, HD = q.shape
    NKV = k.shape[2]
    Skv = k.shape[1]
    G = NQ // NKV
    if scale is None:
        scale = HD**-0.5
    qg = q.reshape(B, Sq, NKV, G, HD)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32) * scale
    ok = _block_mask(
        jnp.arange(Sq), jnp.arange(Skv), causal=causal, window=window, prefix_len=prefix_len
    )
    s = jnp.where(_expand_mask(ok), s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, NQ, HD).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode-time attention: one query token against a (ring-buffer) KV cache.
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window: int = 0, scale=None):
    """Single-step attention over a cache.

    Args:
      q: (B, 1, NQ, HD) query for the new token.
      k_cache, v_cache: (B, Scache, NKV, HD).
      slot_pos: (B, Scache) absolute position stored in each slot (-1 empty).
      pos: (B,) position of the query token.
      window: sliding window (0 = unlimited).
    """
    B, _, NQ, HD = q.shape
    NKV = k_cache.shape[2]
    G = NQ // NKV
    if scale is None:
        scale = HD**-0.5
    qg = q.reshape(B, 1, NKV, G, HD)
    s = (
        jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache, preferred_element_type=jnp.float32)
        * scale
    )  # (B, KV, G, 1, Scache)
    ok = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        ok &= slot_pos > (pos[:, None] - window)
    s = jnp.where(ok[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, NQ, HD).astype(q.dtype)


def paged_decode_attention(
    q, k_pool, v_pool, page_tables, pos, *, window: int = 0, scale=None
):
    """Single-step attention over a block-paged KV pool.

    The continuous-batching layout: instead of one contiguous cache per
    row, each row owns a *page table* into a shared physical pool.  Pages
    are append-only — the entry at a row's dense index ``i`` (page
    ``i // page``, offset ``i % page``) holds exactly absolute position
    ``i`` — so validity is just ``i <= pos`` and no stored slot-position
    array is needed.  Table entries past a row's reservation point at the
    trash page (0); their dense indices always exceed ``pos``, so the
    causal mask keeps them unread.

    Args:
      q: (B, 1, NQ, HD) query for the new token.
      k_pool, v_pool: (P, page, NKV, HD) physical page pools.
      page_tables: (B, NB) int32 page ids per row.
      pos: (B,) absolute position of each row's query token.
      window: sliding window (0 = unlimited).

    This is the runtime (pure-jnp) path; the Pallas TPU substrate with the
    same table-indexed layout is ``repro.kernels.decode_attention.
    decode_attention_paged_fwd``.
    """
    P, page, NKV, HD = k_pool.shape
    B, NB = page_tables.shape
    S = NB * page
    flat = page_tables[:, :, None] * page + jnp.arange(page)[None, None, :]
    flat = flat.reshape(B, S)  # (B, S) indices into the flattened pool
    k_dense = k_pool.reshape(P * page, NKV, HD)[flat]  # (B, S, NKV, HD)
    v_dense = v_pool.reshape(P * page, NKV, HD)[flat]
    slot_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return decode_attention(
        q, k_dense, v_dense, slot_pos, pos, window=window, scale=scale
    )
