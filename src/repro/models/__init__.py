"""Pure-JAX composable LM zoo (the serving substrate under MDInference)."""
from repro.models.config import ModelConfig
from repro.models import transformer, attention, layers, moe, rglru, xlstm

__all__ = ["ModelConfig", "transformer", "attention", "layers", "moe", "rglru", "xlstm"]
