"""Quickstart: the MDInference algorithm in 40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import paper_zoo
from repro.core import (
    DEFAULT_ON_DEVICE,
    FixedCVNetwork,
    SimConfig,
    compute_budget,
    run_simulation,
    select_ref,
)

# --- one request through the three-stage selection ------------------------
zoo = paper_zoo()  # Table III: 11 functionally-equivalent image classifiers
t_sla, t_nw = 250.0, 100.0  # SLA and estimated network time (ms)
budget = compute_budget(t_sla, t_nw)

rng = np.random.default_rng(0)
sel = select_ref(zoo, budget, rng)
print(f"budget {budget:.0f}ms -> base={zoo[sel.base_index].name!r} "
      f"selected={zoo[sel.index].name!r} "
      f"(M_E size {len(sel.exploration_set)})")

# --- 10,000 simulated requests, with and without duplication ---------------
net = FixedCVNetwork(mean_ms=100.0, cv=0.5)  # the paper's 100 +- 50 ms network
for dup in (False, True):
    res = run_simulation(
        SimConfig(
            registry=zoo,
            algorithm="mdinference",
            t_sla_ms=t_sla,
            n_requests=10_000,
            network=net,
            duplication=dup,
            ondevice=DEFAULT_ON_DEVICE,
            seed=0,
        )
    )
    m = res.metrics
    print(f"duplication={dup!s:5s}  {m.row()}")

# Compare against the static baselines of the paper's Table IV.
for alg in ("static_latency", "static_accuracy", "static_greedy"):
    m = run_simulation(
        SimConfig(registry=zoo, algorithm=alg, t_sla_ms=t_sla,
                  n_requests=10_000, network=net, duplication=True, seed=0)
    ).metrics
    print(f"{alg:16s}  {m.row()}")
