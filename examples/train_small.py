"""Train a ~100M-parameter llama-family model for a few hundred steps.

Demonstrates the full training substrate on CPU: synthetic resumable data,
AdamW + cosine schedule, remat, checkpointing every 100 steps.

Run:  PYTHONPATH=src python examples/train_small.py
(~100M params is slow on one CPU core; pass --d-model 128 for a fast demo.)
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = [
        "--arch", "llama3-8b",
        "--d-model", "512",       # 512 wide x 8 layers + 256-wide head ~ 100M
        "--layers", "8",
        "--steps", "300",
        "--batch", "8",
        "--seq", "256",
        "--ckpt-dir", "/tmp/repro_train_small",
        "--ckpt-every", "100",
    ] + sys.argv[1:]
    raise SystemExit(main(args))
