"""Network-adaptiveness demo (paper Fig 4/5): sweep CV, watch MDInference
trade model diversity for SLA attainment.

Run:  PYTHONPATH=src python examples/network_sweep.py
"""
from repro.configs import paper_zoo
from repro.core import FixedCVNetwork, SimConfig, run_simulation

zoo = paper_zoo()
print(f"{'CV':>4s}  {'SLA=100ms':^34s}  {'SLA=250ms':^34s}")
print(f"{'':4s}  {'acc':>7s} {'attain':>7s} {'models':>7s}     "
      f"{'acc':>7s} {'attain':>7s} {'models':>7s}")
for cv in (0.0, 0.2, 0.4, 0.6, 0.74, 1.0):
    cols = []
    for sla in (100.0, 250.0):
        m = run_simulation(
            SimConfig(
                registry=zoo, algorithm="mdinference", t_sla_ms=sla,
                n_requests=10_000, network=FixedCVNetwork(100.0, cv), seed=1,
            )
        ).metrics
        diverse = sum(1 for v in m.model_usage.values() if v > 0.01)
        cols.append(f"{m.aggregate_accuracy:7.2f} {m.sla_attainment*100:6.1f}% {diverse:7d}")
    print(f"{cv:4.2f}  {cols[0]}     {cols[1]}")

print("\nAs the paper observes: with a dead-stable network at SLA=100ms the "
      "budget is always zero (attainment<50%); variability lets MDInference "
      "exploit fast draws with bigger models.")

# Measured-trace sweep (Table IV flavored), served through the *batched*
# online scheduler: same policy, chunked decide/observe with live EWMA
# profile updates, hedged with the paper's on-device vision model (same
# ImageNet accuracy scale as the zoo).
import numpy as np

from repro.core import DEFAULT_ON_DEVICE, NAMED_TRACES
from repro.serving.scheduler import MDInferenceScheduler, SchedulerConfig

print(f"\n{'trace':>12s}  {'acc':>7s} {'attain':>7s} {'ondev':>7s}")
for name, factory in NAMED_TRACES.items():
    t_nw = factory().sample(np.random.default_rng(7), 10_000)
    sched = MDInferenceScheduler(
        zoo, DEFAULT_ON_DEVICE,
        SchedulerConfig(t_sla_ms=250.0, seed=7, chunk_size=1024),
    )
    m = sched.run_trace(t_nw)
    print(f"{name:>12s}  {m.aggregate_accuracy:7.2f} "
          f"{m.sla_attainment*100:6.1f}% {m.ondevice_reliance*100:6.2f}%")

print("\nOnline serving bounds latency at the SLA on every trace; the "
      "on-device hedge absorbs exactly the tail the network model plants "
      "(LTE's handover outages show the highest reliance).")
