"""End-to-end serving example: the async request-lifecycle API, for real.

Part 1 drives the client surface by hand: an ``InferenceClient`` over a
``ServingLoop`` wired to two real execution tiers (remote ``JitBackend``
variants + the ``OnDeviceBackend`` duplicate).  ``submit`` returns an
``InferenceFuture`` immediately (QUEUED); a scheduling tick moves it
through SCHEDULED/EXECUTING — dispatching the remote batch and the hedged
duplicate *concurrently* — and ``result()`` returns the resolved
``CompletedRequest``, including which tier won the race.

Part 2 serves an open-loop Poisson trace through the same tick path
(``launch.serve`` / ``ServingLoop.drain_trace``): the paper's Figure 1(d)
running for real on both tiers, with continuous batching and measured
hedged duplication bounding every response at the SLA — here over a
2-replica ``ClusterBackend`` pool with join-shortest-queue routing (the
hedge duplicate stays a device-side singleton outside the pool).

Run:  PYTHONPATH=src python examples/serve_mdinference.py
"""
import numpy as np

from repro.launch.serve import build_engine, main
from repro.serving import InferenceClient, MDInferenceScheduler, SchedulerConfig

PROMPT, GEN = 16, 4


def client_demo():
    print("=== part 1: InferenceClient futures over a two-tier ServingLoop ===")
    engine = build_engine(max_len=PROMPT + GEN + 8, measured_hedge=True)
    registry = engine.measure_profiles(prompt_len=PROMPT, gen_tokens=GEN, trials=2)
    ondevice = engine.hedge_backend.measure_profile(
        prompt_len=PROMPT, gen_tokens=GEN, trials=2
    )
    sched = MDInferenceScheduler(
        registry, ondevice, SchedulerConfig(t_sla_ms=2_000.0)
    )
    loop = engine.make_loop(sched)  # dispatch="async": tiers overlap
    client = InferenceClient(loop)

    rng = np.random.default_rng(0)
    # Three requests: generous network, a tight per-request SLA, a cancel.
    f_ok = client.submit(rng.integers(0, 256, PROMPT), GEN, t_nw_est_ms=80.0)
    f_tight = client.submit(
        rng.integers(0, 256, PROMPT), GEN, sla=10.0, t_nw_est_ms=80.0
    )
    f_cancel = client.submit(rng.integers(0, 256, PROMPT), GEN, t_nw_est_ms=80.0)
    print(f"submitted: {f_ok.state.value}, {f_tight.state.value}, "
          f"{f_cancel.state.value}")
    f_cancel.cancel()  # still QUEUED: freed before it occupies a batch slot

    done = f_ok.result()  # drives the loop: one tick serves the chunk
    print(f"f_ok     -> {done.model_name:10s} race={done.race_resolution:12s} "
          f"latency={done.latency_ms:7.1f}ms tokens={done.tokens.tolist()}")
    tight = f_tight.result()  # 10ms SLA < network: the duplicate answered
    print(f"f_tight  -> {tight.model_name:10s} race={tight.race_resolution:12s} "
          f"latency={tight.latency_ms:7.1f}ms (10ms SLA)")
    print(f"f_cancel -> cancelled={f_cancel.cancelled()}")
    print(f"lifecycle of f_ok: submitted@{f_ok.submitted_ms:.0f}ms "
          f"scheduled@{f_ok.scheduled_ms:.0f}ms "
          f"tiers dispatched {sorted(f_ok.tier_dispatch_wall_ms)} "
          f"resolved@{f_ok.resolved_ms:.0f}ms\n")


if __name__ == "__main__":
    client_demo()
    print("=== part 2: open-loop trace through a 2-replica cluster ===")
    raise SystemExit(
        main(["--requests", "30", "--sla", "2500", "--gen", "8", "--rate", "20",
              "--hedge", "measured", "--dispatch", "async",
              "--replicas", "2", "--router", "least_inflight"])
    )
