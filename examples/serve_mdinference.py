"""End-to-end serving example: MDInference over REAL two-tier execution.

Three functionally-equivalent LM tiers (tiny configs of the gemma / llama3 /
qwen3 families) are built and profiled with real wall-clock measurements;
an open-loop Poisson request stream is then served with continuous
batching: each scheduling window is decided in one batched scheduler call,
requests that picked the same tier run as one real ``generate`` batch, and
every hedged request *also* runs on a real on-device hedge variant
(``OnDeviceBackend``) so duplication resolves on measured wall time and
bounds every response at the SLA.  This is the paper's Figure 1(d) running
for real on both tiers.

Run:  PYTHONPATH=src python examples/serve_mdinference.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    raise SystemExit(
        main(["--requests", "30", "--sla", "2500", "--gen", "8", "--rate", "20",
              "--hedge", "measured"])
    )
